//! Cross-language numeric integration test: the rust PJRT engine must
//! reproduce the exact outputs python computed through the same HLO
//! graphs (artifacts/golden.json, written by `python -m compile.aot`).
//!
//! This is the core correctness signal for the whole AOT bridge: weights
//! npz -> device buffers -> execute_b -> logits.

use msao::runtime::{Arg, HostTensor, Manifest, OutPlan, SiteThread};
use msao::util::json::Value;

fn art_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Self-skip (cleanly green) when the AOT artifacts have not been
/// built, so `cargo test -q` can gate CI without the JAX toolchain.
fn artifacts_built() -> bool {
    art_dir().join("manifest.json").exists() && art_dir().join("golden.json").exists()
}

fn golden() -> Value {
    let text = std::fs::read_to_string(art_dir().join("golden.json"))
        .expect("golden.json missing; run `make artifacts`");
    Value::parse(&text).unwrap()
}

fn vecf(v: &Value, key: &str) -> Vec<f32> {
    v.req(key)
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as f32)
        .collect()
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    let mut worst = 0f32;
    for (g, w) in got.iter().zip(want) {
        worst = worst.max((g - w).abs());
    }
    assert!(worst <= tol, "{what}: max abs diff {worst} > {tol}");
}

/// Fixed inputs mirroring aot.make_golden.
struct Fixed {
    text: Vec<i32>,
    vis: Vec<f32>,
    aud: Vec<f32>,
}

fn fixed(m: &Manifest) -> Fixed {
    let c = &m.constants;
    let mut text = vec![c.pad(); c.text_slots()];
    text[0] = 257; // BOS
    text[1] = 72;
    text[2] = 73;
    text[3] = c.get("SEP").unwrap() as i32;
    let n = c.vis_slots() * c.d_enc();
    let vis: Vec<f32> = (0..n)
        .map(|i| -1.0 + 2.0 * i as f32 / (n - 1) as f32)
        .collect();
    let aud = vec![0f32; c.aud_slots() * c.d_enc()];
    Fixed { text, vis, aud }
}

#[test]
fn engine_reproduces_python_golden_outputs() {
    if !artifacts_built() {
        eprintln!("skipped: artifacts/ not built (run `make artifacts`)");
        return;
    }
    let m = Manifest::load(art_dir()).expect("run `make artifacts` first");
    let g = golden();
    let c = m.constants.clone();
    let f = fixed(&m);

    let site = SiteThread::spawn(
        "test",
        &m,
        &[
            "draft_prefill",
            "draft_decode",
            "full_prefill",
            "full_verify",
            "vision_encoder",
            "probe_spatial",
        ],
    )
    .expect("spawn site");
    let h = &site.handle;

    let prefill_args = |_tag: &str| {
        vec![
            Arg::Host(HostTensor::i32(f.text.clone(), vec![c.text_slots()])),
            Arg::Host(HostTensor::scalar_i32(4)),
            Arg::Host(HostTensor::f32(
                f.vis.clone(),
                vec![c.vis_slots(), c.d_enc()],
            )),
            Arg::Host(HostTensor::scalar_i32(100)),
            Arg::Host(HostTensor::f32(
                f.aud.clone(),
                vec![c.aud_slots(), c.d_enc()],
            )),
            Arg::Host(HostTensor::scalar_i32(0)),
        ]
    };

    // --- draft prefill + decode ------------------------------------------
    let out = h
        .call(
            "draft_prefill",
            prefill_args("draft"),
            OutPlan::Kv { kv_index: 0, replace: None },
        )
        .unwrap();
    let kv = out.kv.expect("kv handle");
    let logits = out.host[1].as_ref().unwrap().as_f32().unwrap();
    assert_close(logits, &vecf(&g, "draft_prefill_logits"), 5e-3, "draft_prefill");

    let out = h
        .call(
            "draft_decode",
            vec![
                Arg::Kv(kv),
                Arg::Host(HostTensor::scalar_i32(c.gen_off() as i32)),
                Arg::Host(HostTensor::i32(vec![42], vec![1])),
                Arg::Host(HostTensor::scalar_i32(100)),
                Arg::Host(HostTensor::scalar_i32(0)),
                Arg::Host(HostTensor::scalar_i32(4)),
            ],
            OutPlan::Kv { kv_index: 1, replace: Some(kv) },
        )
        .unwrap();
    let logits = out.host[0].as_ref().unwrap().as_f32().unwrap();
    assert_close(logits, &vecf(&g, "draft_decode_logits"), 5e-3, "draft_decode");

    // --- full prefill + verify -------------------------------------------
    let out = h
        .call(
            "full_prefill",
            prefill_args("full"),
            OutPlan::Kv { kv_index: 0, replace: None },
        )
        .unwrap();
    let kvf = out.kv.unwrap();
    let logits = out.host[1].as_ref().unwrap().as_f32().unwrap();
    assert_close(logits, &vecf(&g, "full_prefill_logits"), 5e-3, "full_prefill");

    let out = h
        .call(
            "full_verify",
            vec![
                Arg::Kv(kvf),
                Arg::Host(HostTensor::scalar_i32(c.gen_off() as i32)),
                Arg::Host(HostTensor::i32(vec![42, 7, 300, 264, 11, 99], vec![6])),
                Arg::Host(HostTensor::scalar_i32(100)),
                Arg::Host(HostTensor::scalar_i32(0)),
                Arg::Host(HostTensor::scalar_i32(4)),
            ],
            OutPlan::Kv { kv_index: 1, replace: Some(kvf) },
        )
        .unwrap();
    let vlg = out.host[0].as_ref().unwrap().as_f32().unwrap();
    let vocab = c.vocab();
    assert_close(&vlg[..vocab], &vecf(&g, "full_verify_row0"), 5e-3, "verify row0");
    assert_close(
        &vlg[5 * vocab..6 * vocab],
        &vecf(&g, "full_verify_row5"),
        5e-3,
        "verify row5",
    );

    // --- vision encoder + spatial probe ------------------------------------
    let n = c.n_patch() * c.patch_dim();
    let patches: Vec<f32> = (0..n).map(|i| (i as f32 * 0.01).sin()).collect();
    let out = h
        .call(
            "vision_encoder",
            vec![Arg::Host(HostTensor::f32(
                patches,
                vec![c.n_patch(), c.patch_dim()],
            ))],
            OutPlan::AllHost,
        )
        .unwrap();
    let pooled = out.host[3].as_ref().unwrap().as_f32().unwrap();
    assert_close(pooled, &vecf(&g, "vision_pooled"), 5e-3, "vision_pooled");

    let feat = out.host[2].as_ref().unwrap().clone();
    let out = h
        .call("probe_spatial", vec![Arg::Host(feat)], OutPlan::AllHost)
        .unwrap();
    let map = out.host[0].as_ref().unwrap().as_f32().unwrap();
    assert_close(
        &map[..c.grid()],
        &vecf(&g, "probe_spatial_map_row0"),
        5e-3,
        "probe_spatial",
    );

    // KV slab hygiene.
    let stats = h.stats().unwrap();
    assert_eq!(stats.kv_entries, 2);
    h.free_kv(kv);
    h.free_kv(kvf);
}
