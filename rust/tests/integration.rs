//! Integration tests over the full coordinator stack (real PJRT engines,
//! virtual testbed). One `Coordinator` is shared across tests via a
//! leaked singleton: engine startup (compile 11 graphs + calibration)
//! costs ~10 s and tests must not pay it repeatedly.

use std::sync::{Mutex, OnceLock};

use msao::baselines::{cloud_only, edge_only, perllm, Baseline};
use msao::config::Config;
use msao::coordinator::mas::run_probe;
use msao::coordinator::planner::{plan, PlanCtx};
use msao::coordinator::{
    serve, testbed, Batcher, Coordinator, Mode, PolicyKind, TraceSpec,
};
use msao::metrics::summarize;
use msao::sparsity::Modality;
use msao::workload::{Benchmark, Generator, Item};

/// MSAO trace spec with the policy default concurrency (what the old
/// `serve_trace` entrypoint used).
fn msao_spec(items: Vec<Item>, arrivals: Vec<f64>, mode: Mode, seed: u64) -> TraceSpec {
    TraceSpec::new(PolicyKind::Msao(mode)).trace(items, arrivals).seed(seed)
}

fn coord() -> std::sync::MutexGuard<'static, Coordinator> {
    static C: OnceLock<Mutex<Coordinator>> = OnceLock::new();
    C.get_or_init(|| {
        let mut cfg = Config::default();
        cfg.artifacts_dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
        Mutex::new(Coordinator::new(cfg).expect("run `make artifacts` first"))
    })
    // Poison-tolerant: one failing test must not cascade into the rest.
    .lock()
    .unwrap_or_else(|e| e.into_inner())
}

#[test]
fn probe_identifies_relevant_modality_and_salience() {
    let c = coord();
    let mut gen = Generator::new(5);
    let mut modal_hits = 0;
    let mut n = 0;
    for _ in 0..6 {
        let item = gen.mmbench_item();
        let probe = run_probe(&c.eng, &c.cfg.msao, &item).unwrap();
        let best = probe
            .mas
            .iter()
            .filter(|m| probe.present[m.modality.index()])
            .max_by(|a, b| a.beta.partial_cmp(&b.beta).unwrap())
            .unwrap();
        // Text questions always reference SOME modality; the probe's top
        // beta should usually be the ground-truth relevant one.
        if best.modality == item.relevant {
            modal_hits += 1;
        }
        n += 1;
        // Structural invariants.
        for m in &probe.mas {
            assert!((0.0..=1.0).contains(&m.mas));
        }
        if let Some(p) = &probe.pruned {
            assert!(p.count <= 192);
        }
    }
    assert!(modal_hits * 2 >= n, "modal probe hit {modal_hits}/{n}");
}

#[test]
fn probe_pruning_keeps_salient_patches() {
    let c = coord();
    let mut gen = Generator::new(6);
    let item = gen.vqa_item();
    let probe = run_probe(&c.eng, &c.cfg.msao, &item).unwrap();
    let p = probe.pruned.as_ref().unwrap();
    let sal = item.salient.as_ref().unwrap();
    let total_sal = sal.iter().filter(|&&s| s).count();
    let kept_sal = p.idx[..p.count]
        .iter()
        .filter(|&&i| i >= 0 && sal[i as usize])
        .count();
    // The trained spatial probe must retain nearly all salient patches.
    assert!(
        kept_sal as f64 >= 0.9 * total_sal as f64,
        "kept {kept_sal}/{total_sal} salient"
    );
    // And prune most of the background.
    let bg_total = 256 - total_sal;
    let bg_kept = p.count - kept_sal;
    assert!(
        (bg_kept as f64) < 0.3 * bg_total as f64,
        "kept {bg_kept}/{bg_total} background"
    );
}

#[test]
fn planner_respects_mas_floor_and_quality_bound() {
    let c = coord();
    let mut gen = Generator::new(7);
    let item = gen.vqa_item();
    let probe = run_probe(&c.eng, &c.cfg.msao, &item).unwrap();
    let p = plan(&PlanCtx {
        cfg: &c.cfg,
        item: &item,
        probe: &probe,
        p_conf: 0.7,
        n_out: 64,
        seed: 1,
    })
    .unwrap();
    // beta_m >= 1 - MAS_m (Eq. 11 last constraint).
    for m in [Modality::Image, Modality::Video, Modality::Audio] {
        if item.has(m) {
            let floor = 1.0 - probe.mas[m.index()].mas;
            assert!(
                p.beta[m.index()] >= floor - 1e-9,
                "{}: beta {} < floor {floor}",
                m.name(),
                p.beta[m.index()]
            );
        }
    }
    assert!(p.delta_q_est <= c.cfg.msao.epsilon_q + 1e-9, "dq {}", p.delta_q_est);
    assert!(p.n_draft >= 1 && p.n_draft <= c.cfg.msao.n_max);
    assert!(p.bytes_up > 0);
}

#[test]
fn msao_beats_cloud_only_latency_and_flops_under_load() {
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let mut gen = Generator::new(42);
    let items = gen.items(Benchmark::Vqa, 10);
    let arrivals = gen.arrivals(10, 1.3);
    let msao = summarize(
        &serve(&mut c, &msao_spec(items.clone(), arrivals.clone(), Mode::Msao, 1))
            .unwrap()
            .records,
    );
    // Concurrency 1 = the sequential loop the baselines ran pre-unification.
    let cloud_spec = TraceSpec::new(PolicyKind::CloudOnly)
        .trace(items, arrivals)
        .seed(1)
        .concurrency(1);
    let cloud = summarize(&serve(&mut c, &cloud_spec).unwrap().records);
    assert!(
        msao.latency_mean_s < cloud.latency_mean_s,
        "MSAO {} vs cloud {}",
        msao.latency_mean_s,
        cloud.latency_mean_s
    );
    assert!(msao.tflops_per_req < 0.7 * cloud.tflops_per_req);
    assert!(msao.throughput_tps > cloud.throughput_tps);
    // Speculation is actually happening.
    assert!(msao.acceptance_rate > 0.5, "acceptance {}", msao.acceptance_rate);
    assert!(msao.tokens_per_req > 32.0);
}

#[test]
fn ablations_degrade_the_right_metrics() {
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let mut gen = Generator::new(77);
    let items = gen.items(Benchmark::Vqa, 10);
    let arrivals = gen.arrivals(10, 1.3);
    let full = summarize(
        &serve(&mut c, &msao_spec(items.clone(), arrivals.clone(), Mode::Msao, 2))
            .unwrap()
            .records,
    );
    let no_collab = summarize(
        &serve(&mut c, &msao_spec(items.clone(), arrivals.clone(), Mode::NoCollabSched, 2))
            .unwrap()
            .records,
    );
    let no_aware = summarize(
        &serve(&mut c, &msao_spec(items, arrivals, Mode::NoModalityAware, 2))
            .unwrap()
            .records,
    );
    // Static scheduling costs latency (Fig. 9 right).
    assert!(
        no_collab.latency_mean_s > 1.2 * full.latency_mean_s,
        "collab {} vs full {}",
        no_collab.latency_mean_s,
        full.latency_mean_s
    );
    // Uniform offloading ships more bytes and burns more compute.
    assert!(no_aware.gb_up_per_req > 1.5 * full.gb_up_per_req);
    assert!(no_aware.tflops_per_req > full.tflops_per_req);
}

#[test]
fn speculative_tokens_match_cloud_greedy_semantics() {
    // Spec decoding with greedy accept must produce tokens the full
    // model endorses: re-scoring the emitted prefix with the full model
    // must reproduce each committed token (verify-consistency).
    let mut c = coord();
    let eng_c = c.eng.c.clone();
    let mut gen = Generator::new(9);
    let items = gen.items(Benchmark::Vqa, 1);
    let res = serve(&mut c, &msao_spec(items, vec![0.0], Mode::Msao, 3)).unwrap();
    let rec = &res.records[0];
    assert!(rec.tokens_out >= 32, "tokens {}", rec.tokens_out);
    assert!(rec.proposed > 0 && rec.accepted <= rec.proposed);
    assert!(rec.mem_edge_gb > 5.0); // weights resident at paper scale
    let _ = eng_c;
}

#[test]
fn scheduler_concurrency_one_reproduces_sequential_fcfs() {
    // The event-driven scheduler at concurrency 1 must reproduce the
    // seed's run-to-completion FCFS loop bit for bit: same tokens, same
    // virtual times, same quality, on an identically seeded testbed.
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let mut gen = Generator::new(31);
    let n = 6;
    let items = gen.items(Benchmark::Vqa, n);
    let arrivals = gen.arrivals(n, 1.3);
    let spec = msao_spec(items.clone(), arrivals.clone(), Mode::Msao, 5).concurrency(1);
    let sched = serve(&mut c, &spec).unwrap();

    // Seed FCFS reference: one request to completion at a time, sharing
    // testbed, batcher and theta exactly like the seed serve_trace did.
    let cfg = c.cfg.clone();
    let mut vc = testbed(&cfg, 5, &PolicyKind::Msao(Mode::Msao).resident_profile());
    let mut batcher = Batcher::new(cfg.serve.batch_wait_ms, cfg.serve.verify_batch, true);
    let mut theta = c.theta();
    for (i, (item, &arr)) in items.iter().zip(&arrivals).enumerate() {
        let rec = c.serve(&mut vc, &mut batcher, &mut theta, item, arr, Mode::Msao).unwrap();
        let s = &sched.records[i];
        assert_eq!(rec.tokens_out, s.tokens_out, "req {i}: tokens");
        assert_eq!(rec.accepted, s.accepted, "req {i}: accepted");
        assert_eq!(rec.proposed, s.proposed, "req {i}: proposed");
        assert_eq!(rec.offloads, s.offloads, "req {i}: offloads");
        assert_eq!(rec.bytes_up, s.bytes_up, "req {i}: bytes_up");
        assert_eq!(rec.t_done.to_bits(), s.t_done.to_bits(), "req {i}: t_done");
        assert_eq!(rec.latency_s.to_bits(), s.latency_s.to_bits(), "req {i}: latency");
        assert_eq!(rec.prefill_s.to_bits(), s.prefill_s.to_bits(), "req {i}: prefill");
        assert_eq!(rec.p_correct.to_bits(), s.p_correct.to_bits(), "req {i}: p_correct");
    }
}

#[test]
fn cross_request_verify_batching_under_concurrent_load() {
    // With >= 8 sessions decoding at once, verify uplinks from different
    // requests interleave on the link and the dynamic batcher must
    // coalesce at least some of them — impossible for the seed's
    // run-to-completion loop, whose rounds are a full draft block apart.
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let mut gen = Generator::new(99);
    let n = 12;
    let items = gen.items(Benchmark::Vqa, n);
    // Burst arrivals: everything lands within ~100 ms.
    let arrivals: Vec<f64> = (0..n).map(|i| i as f64 * 0.01).collect();
    let spec = msao_spec(items, arrivals, Mode::Msao, 7).concurrency(8);
    let res = serve(&mut c, &spec).unwrap();
    assert!(
        res.batch_amortization > 0.0,
        "no cross-request piggyback (amortization {})",
        res.batch_amortization
    );
    assert!(res.records.iter().all(|r| r.tokens_out > 0));
}

#[test]
fn concurrent_poisson_trace_completes_every_session() {
    // No session starves under the event-driven interleave: every
    // request of a Poisson trace finishes with sane times and tokens.
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let mut gen = Generator::new(17);
    let n = 16;
    let items = gen.items(Benchmark::MmBench, n);
    let arrivals = gen.arrivals(n, 4.0);
    let spec = msao_spec(items, arrivals, Mode::Msao, 11).concurrency(8);
    let res = serve(&mut c, &spec).unwrap();
    assert_eq!(res.records.len(), n);
    for (i, r) in res.records.iter().enumerate() {
        assert!(r.tokens_out > 0, "req {i} produced no tokens");
        assert!(r.t_done > r.t_arrival, "req {i}: non-causal completion");
        assert!(r.latency_s.is_finite() && r.latency_s > 0.0, "req {i}: latency");
    }
}

#[test]
fn perllm_lands_between_edge_and_cloud_accuracy() {
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let mut gen = Generator::new(123);
    let n = 14;
    let items = gen.items(Benchmark::Vqa, n);
    let arrivals = gen.arrivals(n, 1.3);
    let spec = TraceSpec::new(PolicyKind::PerLlm)
        .trace(items, arrivals)
        .seed(4)
        .concurrency(1);
    let per = summarize(&serve(&mut c, &spec).unwrap().records);
    // p_correct (not the sampled accuracy, which is noisy at n=14) must
    // sit between the edge and cloud capability anchors.
    let recs = serve(&mut c, &spec).unwrap();
    let mean_p: f64 = recs.records.iter().map(|r| r.p_correct).sum::<f64>() / n as f64;
    assert!(mean_p > 0.55 && mean_p < 0.80, "PerLLM mean p_correct {mean_p}");
    assert!(per.tflops_per_req > 0.0);
}

#[test]
fn baseline_sessions_reproduce_sequential_loop_bit_for_bit() {
    // Golden equivalence, one sub-case per baseline: the event-driven
    // session path at concurrency 1 must reproduce the pre-refactor
    // run-to-completion loop bit for bit — same tokens, same virtual
    // times, same bytes, same quality — on an identically seeded
    // testbed. The references are the straight-line `serve` functions
    // each baseline module keeps verbatim from before the refactor.
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    for (policy, baseline) in [
        (PolicyKind::CloudOnly, Baseline::CloudOnly),
        (PolicyKind::EdgeOnly, Baseline::EdgeOnly),
        (PolicyKind::PerLlm, Baseline::PerLlm),
    ] {
        let mut gen = Generator::new(31);
        let n = 5;
        let items = gen.items(Benchmark::Vqa, n);
        let arrivals = gen.arrivals(n, 1.3);
        let spec = TraceSpec::new(policy.clone())
            .trace(items.clone(), arrivals.clone())
            .seed(5)
            .concurrency(1);
        let new = serve(&mut c, &spec).unwrap();
        assert_eq!(new.records.len(), n);

        // The old loop: each request served to completion in arrival
        // order on an identically configured shared testbed (what
        // `serve_trace_baseline` did before the unification).
        let cfg = c.cfg.clone();
        let mut vc = testbed(&cfg, 5, &policy.resident_profile());
        for (i, (item, &arr)) in items.iter().zip(&arrivals).enumerate() {
            let rec = match baseline {
                Baseline::CloudOnly => cloud_only::serve(&mut c, &mut vc, item, arr),
                Baseline::EdgeOnly => edge_only::serve(&mut c, &mut vc, item, arr),
                Baseline::PerLlm => perllm::serve(&mut c, &mut vc, item, arr),
            }
            .unwrap();
            let s = &new.records[i];
            assert_eq!(rec.tokens_out, s.tokens_out, "{policy:?} req {i}: tokens");
            assert_eq!(rec.bytes_up, s.bytes_up, "{policy:?} req {i}: bytes_up");
            assert_eq!(rec.bytes_down, s.bytes_down, "{policy:?} req {i}: bytes_down");
            assert_eq!(rec.t_done.to_bits(), s.t_done.to_bits(), "{policy:?} req {i}: t_done");
            assert_eq!(
                rec.latency_s.to_bits(),
                s.latency_s.to_bits(),
                "{policy:?} req {i}: latency"
            );
            assert_eq!(
                rec.prefill_s.to_bits(),
                s.prefill_s.to_bits(),
                "{policy:?} req {i}: prefill"
            );
            assert_eq!(
                rec.flops_edge.to_bits(),
                s.flops_edge.to_bits(),
                "{policy:?} req {i}: flops_edge"
            );
            assert_eq!(
                rec.flops_cloud.to_bits(),
                s.flops_cloud.to_bits(),
                "{policy:?} req {i}: flops_cloud"
            );
            assert_eq!(
                rec.mem_serving_gb.to_bits(),
                s.mem_serving_gb.to_bits(),
                "{policy:?} req {i}: mem_serving"
            );
            assert_eq!(
                rec.p_correct.to_bits(),
                s.p_correct.to_bits(),
                "{policy:?} req {i}: p_correct"
            );
            assert_eq!(rec.correct, s.correct, "{policy:?} req {i}: correct");
        }
        assert_eq!(new.uplink_bytes, vc.link.uplink_bytes, "{policy:?}: uplink bytes");
        assert_eq!(new.downlink_bytes, vc.link.downlink_bytes, "{policy:?}: downlink bytes");
    }
}

#[test]
fn mixed_policy_trace_serves_heterogeneous_tenants() {
    // A PerRequest trace mixes MSAO and baseline sessions on one shared
    // cluster under the event-driven interleave: every session must
    // complete (starvation-free) with causal times, and per-tenant
    // signatures must survive (edge-only ships nothing up; cloud-only
    // ships raw payloads).
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let mut gen = Generator::new(55);
    let n = 8;
    let items = gen.items(Benchmark::Vqa, n);
    let arrivals: Vec<f64> = (0..n).map(|i| i as f64 * 0.05).collect();
    let policies: Vec<PolicyKind> = (0..n)
        .map(|i| match i % 4 {
            0 => PolicyKind::Msao(Mode::Msao),
            1 => PolicyKind::CloudOnly,
            2 => PolicyKind::EdgeOnly,
            _ => PolicyKind::PerLlm,
        })
        .collect();
    let spec = TraceSpec::new(PolicyKind::PerRequest(policies))
        .trace(items, arrivals)
        .seed(13)
        .concurrency(4);
    let res = serve(&mut c, &spec).unwrap();
    assert_eq!(res.records.len(), n);
    for (i, r) in res.records.iter().enumerate() {
        assert!(r.tokens_out > 0, "req {i} produced no tokens");
        assert!(r.t_done > r.t_arrival, "req {i}: non-causal completion");
        assert!(r.latency_s.is_finite() && r.latency_s > 0.0, "req {i}: latency");
    }
    for i in (2..n).step_by(4) {
        assert_eq!(res.records[i].bytes_up, 0, "edge-only req {i} used the uplink");
    }
    for i in (1..n).step_by(4) {
        assert!(res.records[i].bytes_up > 0, "cloud-only req {i} shipped nothing");
    }
}
