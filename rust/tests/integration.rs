//! Integration tests over the full coordinator stack (real PJRT engines,
//! virtual testbed). One `Coordinator` is shared across tests via a
//! leaked singleton: engine startup (compile 11 graphs + calibration)
//! costs ~10 s and tests must not pay it repeatedly.

use std::sync::{Mutex, OnceLock};

use msao::baselines::{cloud_only, edge_only, perllm, Baseline};
use msao::cluster::NetEstimate;
use msao::config::{Config, EdgeSiteCfg, FaultsCfg, NetworkDynamics, NetworkScenario, Segment};
use msao::coordinator::mas::run_probe;
use msao::coordinator::planner::{plan, PlanCtx};
use msao::coordinator::{
    serve, serve_materialized_ref, session_seed, testbed, Assign, Batcher, Coordinator, Mode,
    PolicyKind, Sched, SloClass, TraceSpec,
};
use msao::metrics::summarize;
use msao::scenario::ScenarioSpec;
use msao::sparsity::Modality;
use msao::workload::{Benchmark, Generator, Item};

/// Engine-backed tests need the AOT artifacts; without them every test
/// in this file self-skips (cleanly green) so the CI tier-1 gate can
/// block on `cargo test -q` even where the JAX toolchain is absent.
fn artifacts_built() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_built() {
            eprintln!("skipped: artifacts/ not built (run `make artifacts`)");
            return;
        }
    };
}

/// MSAO trace spec with the policy default concurrency (what the old
/// `serve_trace` entrypoint used).
fn msao_spec(items: Vec<Item>, arrivals: Vec<f64>, mode: Mode, seed: u64) -> TraceSpec {
    TraceSpec::new(PolicyKind::Msao(mode)).trace(items, arrivals).seed(seed)
}

fn coord() -> std::sync::MutexGuard<'static, Coordinator> {
    static C: OnceLock<Mutex<Coordinator>> = OnceLock::new();
    C.get_or_init(|| {
        let mut cfg = Config::default();
        cfg.artifacts_dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
        Mutex::new(Coordinator::new(cfg).expect("run `make artifacts` first"))
    })
    // Poison-tolerant: one failing test must not cascade into the rest.
    .lock()
    .unwrap_or_else(|e| e.into_inner())
}

#[test]
fn probe_identifies_relevant_modality_and_salience() {
    require_artifacts!();
    let c = coord();
    let mut gen = Generator::new(5);
    let mut modal_hits = 0;
    let mut n = 0;
    for _ in 0..6 {
        let item = gen.mmbench_item();
        let probe = run_probe(&c.eng, &c.cfg.msao, &item).unwrap();
        let best = probe
            .mas
            .iter()
            .filter(|m| probe.present[m.modality.index()])
            .max_by(|a, b| a.beta.partial_cmp(&b.beta).unwrap())
            .unwrap();
        // Text questions always reference SOME modality; the probe's top
        // beta should usually be the ground-truth relevant one.
        if best.modality == item.relevant {
            modal_hits += 1;
        }
        n += 1;
        // Structural invariants.
        for m in &probe.mas {
            assert!((0.0..=1.0).contains(&m.mas));
        }
        if let Some(p) = &probe.pruned {
            assert!(p.count <= 192);
        }
    }
    assert!(modal_hits * 2 >= n, "modal probe hit {modal_hits}/{n}");
}

#[test]
fn probe_pruning_keeps_salient_patches() {
    require_artifacts!();
    let c = coord();
    let mut gen = Generator::new(6);
    let item = gen.vqa_item();
    let probe = run_probe(&c.eng, &c.cfg.msao, &item).unwrap();
    let p = probe.pruned.as_ref().unwrap();
    let sal = item.salient.as_ref().unwrap();
    let total_sal = sal.iter().filter(|&&s| s).count();
    let kept_sal = p.idx[..p.count]
        .iter()
        .filter(|&&i| i >= 0 && sal[i as usize])
        .count();
    // The trained spatial probe must retain nearly all salient patches.
    assert!(
        kept_sal as f64 >= 0.9 * total_sal as f64,
        "kept {kept_sal}/{total_sal} salient"
    );
    // And prune most of the background.
    let bg_total = 256 - total_sal;
    let bg_kept = p.count - kept_sal;
    assert!(
        (bg_kept as f64) < 0.3 * bg_total as f64,
        "kept {bg_kept}/{bg_total} background"
    );
}

#[test]
fn planner_respects_mas_floor_and_quality_bound() {
    require_artifacts!();
    let c = coord();
    let mut gen = Generator::new(7);
    let item = gen.vqa_item();
    let probe = run_probe(&c.eng, &c.cfg.msao, &item).unwrap();
    let p = plan(&PlanCtx {
        cfg: &c.cfg,
        item: &item,
        probe: &probe,
        net: NetEstimate {
            bandwidth_mbps: c.cfg.network.bandwidth_mbps,
            rtt_ms: c.cfg.network.rtt_ms,
        },
        p_conf: 0.7,
        n_out: 64,
        seed: 1,
    })
    .unwrap();
    // beta_m >= 1 - MAS_m (Eq. 11 last constraint).
    for m in [Modality::Image, Modality::Video, Modality::Audio] {
        if item.has(m) {
            let floor = 1.0 - probe.mas[m.index()].mas;
            assert!(
                p.beta[m.index()] >= floor - 1e-9,
                "{}: beta {} < floor {floor}",
                m.name(),
                p.beta[m.index()]
            );
        }
    }
    assert!(p.delta_q_est <= c.cfg.msao.epsilon_q + 1e-9, "dq {}", p.delta_q_est);
    assert!((1..=c.cfg.msao.n_max).contains(&p.n_draft));
    assert!(p.bytes_up > 0);
}

#[test]
fn msao_beats_cloud_only_latency_and_flops_under_load() {
    require_artifacts!();
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let mut gen = Generator::new(42);
    let items = gen.items(Benchmark::Vqa, 10);
    let arrivals = gen.arrivals(10, 1.3);
    let msao = summarize(
        &serve(&mut c, &msao_spec(items.clone(), arrivals.clone(), Mode::Msao, 1))
            .unwrap()
            .records,
    );
    // Concurrency 1 = the sequential loop the baselines ran pre-unification.
    let cloud_spec = TraceSpec::new(PolicyKind::CloudOnly)
        .trace(items, arrivals)
        .seed(1)
        .concurrency(1);
    let cloud = summarize(&serve(&mut c, &cloud_spec).unwrap().records);
    assert!(
        msao.latency_mean_s < cloud.latency_mean_s,
        "MSAO {} vs cloud {}",
        msao.latency_mean_s,
        cloud.latency_mean_s
    );
    assert!(msao.tflops_per_req < 0.7 * cloud.tflops_per_req);
    assert!(msao.throughput_tps > cloud.throughput_tps);
    // Speculation is actually happening.
    assert!(msao.acceptance_rate > 0.5, "acceptance {}", msao.acceptance_rate);
    assert!(msao.tokens_per_req > 32.0);
}

#[test]
fn ablations_degrade_the_right_metrics() {
    require_artifacts!();
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let mut gen = Generator::new(77);
    let items = gen.items(Benchmark::Vqa, 10);
    let arrivals = gen.arrivals(10, 1.3);
    let full = summarize(
        &serve(&mut c, &msao_spec(items.clone(), arrivals.clone(), Mode::Msao, 2))
            .unwrap()
            .records,
    );
    let no_collab = summarize(
        &serve(&mut c, &msao_spec(items.clone(), arrivals.clone(), Mode::NoCollabSched, 2))
            .unwrap()
            .records,
    );
    let no_aware = summarize(
        &serve(&mut c, &msao_spec(items, arrivals, Mode::NoModalityAware, 2))
            .unwrap()
            .records,
    );
    // Static scheduling costs latency (Fig. 9 right).
    assert!(
        no_collab.latency_mean_s > 1.2 * full.latency_mean_s,
        "collab {} vs full {}",
        no_collab.latency_mean_s,
        full.latency_mean_s
    );
    // Uniform offloading ships more bytes and burns more compute.
    assert!(no_aware.gb_up_per_req > 1.5 * full.gb_up_per_req);
    assert!(no_aware.tflops_per_req > full.tflops_per_req);
}

#[test]
fn speculative_tokens_match_cloud_greedy_semantics() {
    require_artifacts!();
    // Spec decoding with greedy accept must produce tokens the full
    // model endorses: re-scoring the emitted prefix with the full model
    // must reproduce each committed token (verify-consistency).
    let mut c = coord();
    let eng_c = c.eng.c.clone();
    let mut gen = Generator::new(9);
    let items = gen.items(Benchmark::Vqa, 1);
    let res = serve(&mut c, &msao_spec(items, vec![0.0], Mode::Msao, 3)).unwrap();
    let rec = &res.records[0];
    assert!(rec.tokens_out >= 32, "tokens {}", rec.tokens_out);
    assert!(rec.proposed > 0 && rec.accepted <= rec.proposed);
    assert!(rec.mem_edge_gb > 5.0); // weights resident at paper scale
    let _ = eng_c;
}

#[test]
fn scheduler_concurrency_one_reproduces_sequential_fcfs() {
    require_artifacts!();
    // The event-driven scheduler at concurrency 1 must reproduce the
    // seed's run-to-completion FCFS loop bit for bit: same tokens, same
    // virtual times, same quality, on an identically seeded testbed.
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let mut gen = Generator::new(31);
    let n = 6;
    let items = gen.items(Benchmark::Vqa, n);
    let arrivals = gen.arrivals(n, 1.3);
    let spec = msao_spec(items.clone(), arrivals.clone(), Mode::Msao, 5).concurrency(1);
    let sched = serve(&mut c, &spec).unwrap();

    // Seed FCFS reference: one request to completion at a time on a
    // shared testbed whose edge-0 theta controller and batcher carry
    // the adaptive state across calls — exactly what the trace driver's
    // `prepare` installs on every edge before admitting sessions.
    let cfg = c.cfg.clone();
    let mut vc = testbed(&cfg, 5, &PolicyKind::Msao(Mode::Msao).resident_profile());
    vc.edges[0].theta = c.theta();
    vc.edges[0].batcher = Batcher::new(cfg.serve.batch_wait_ms, cfg.serve.verify_batch, true);
    for (i, (item, &arr)) in items.iter().zip(&arrivals).enumerate() {
        let rec = c.serve(&mut vc, item, arr, Mode::Msao, session_seed(5, i)).unwrap();
        let s = &sched.records[i];
        assert_eq!(rec.tokens_out, s.tokens_out, "req {i}: tokens");
        assert_eq!(rec.accepted, s.accepted, "req {i}: accepted");
        assert_eq!(rec.proposed, s.proposed, "req {i}: proposed");
        assert_eq!(rec.offloads, s.offloads, "req {i}: offloads");
        assert_eq!(rec.bytes_up, s.bytes_up, "req {i}: bytes_up");
        assert_eq!(rec.t_done.to_bits(), s.t_done.to_bits(), "req {i}: t_done");
        assert_eq!(rec.latency_s.to_bits(), s.latency_s.to_bits(), "req {i}: latency");
        assert_eq!(rec.prefill_s.to_bits(), s.prefill_s.to_bits(), "req {i}: prefill");
        assert_eq!(rec.p_correct.to_bits(), s.p_correct.to_bits(), "req {i}: p_correct");
    }
}

#[test]
fn cross_request_verify_batching_under_concurrent_load() {
    require_artifacts!();
    // With >= 8 sessions decoding at once, verify uplinks from different
    // requests interleave on the link and the dynamic batcher must
    // coalesce at least some of them — impossible for the seed's
    // run-to-completion loop, whose rounds are a full draft block apart.
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let mut gen = Generator::new(99);
    let n = 12;
    let items = gen.items(Benchmark::Vqa, n);
    // Burst arrivals: everything lands within ~100 ms.
    let arrivals: Vec<f64> = (0..n).map(|i| i as f64 * 0.01).collect();
    let spec = msao_spec(items, arrivals, Mode::Msao, 7).concurrency(8);
    let res = serve(&mut c, &spec).unwrap();
    assert!(
        res.batch_amortization > 0.0,
        "no cross-request piggyback (amortization {})",
        res.batch_amortization
    );
    assert!(res.records.iter().all(|r| r.tokens_out > 0));
}

#[test]
fn concurrent_poisson_trace_completes_every_session() {
    require_artifacts!();
    // No session starves under the event-driven interleave: every
    // request of a Poisson trace finishes with sane times and tokens.
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let mut gen = Generator::new(17);
    let n = 16;
    let items = gen.items(Benchmark::MmBench, n);
    let arrivals = gen.arrivals(n, 4.0);
    let spec = msao_spec(items, arrivals, Mode::Msao, 11).concurrency(8);
    let res = serve(&mut c, &spec).unwrap();
    assert_eq!(res.records.len(), n);
    for (i, r) in res.records.iter().enumerate() {
        assert!(r.tokens_out > 0, "req {i} produced no tokens");
        assert!(r.t_done > r.t_arrival, "req {i}: non-causal completion");
        assert!(r.latency_s.is_finite() && r.latency_s > 0.0, "req {i}: latency");
    }
}

#[test]
fn perllm_lands_between_edge_and_cloud_accuracy() {
    require_artifacts!();
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let mut gen = Generator::new(123);
    let n = 14;
    let items = gen.items(Benchmark::Vqa, n);
    let arrivals = gen.arrivals(n, 1.3);
    let spec = TraceSpec::new(PolicyKind::PerLlm)
        .trace(items, arrivals)
        .seed(4)
        .concurrency(1);
    let per = summarize(&serve(&mut c, &spec).unwrap().records);
    // p_correct (not the sampled accuracy, which is noisy at n=14) must
    // sit between the edge and cloud capability anchors.
    let recs = serve(&mut c, &spec).unwrap();
    let mean_p: f64 = recs.records.iter().map(|r| r.p_correct).sum::<f64>() / n as f64;
    assert!(mean_p > 0.55 && mean_p < 0.80, "PerLLM mean p_correct {mean_p}");
    assert!(per.tflops_per_req > 0.0);
}

#[test]
fn baseline_sessions_reproduce_sequential_loop_bit_for_bit() {
    require_artifacts!();
    // Golden equivalence, one sub-case per baseline: the event-driven
    // session path at concurrency 1 must reproduce the pre-refactor
    // run-to-completion loop bit for bit — same tokens, same virtual
    // times, same bytes, same quality — on an identically seeded
    // testbed. The references are the straight-line `serve` functions
    // each baseline module keeps verbatim from before the refactor.
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    for (policy, baseline) in [
        (PolicyKind::CloudOnly, Baseline::CloudOnly),
        (PolicyKind::EdgeOnly, Baseline::EdgeOnly),
        (PolicyKind::PerLlm, Baseline::PerLlm),
    ] {
        let mut gen = Generator::new(31);
        let n = 5;
        let items = gen.items(Benchmark::Vqa, n);
        let arrivals = gen.arrivals(n, 1.3);
        let spec = TraceSpec::new(policy.clone())
            .trace(items.clone(), arrivals.clone())
            .seed(5)
            .concurrency(1);
        let new = serve(&mut c, &spec).unwrap();
        assert_eq!(new.records.len(), n);

        // The old loop: each request served to completion in arrival
        // order on an identically configured shared testbed (what
        // `serve_trace_baseline` did before the unification).
        let cfg = c.cfg.clone();
        let mut vc = testbed(&cfg, 5, &policy.resident_profile());
        for (i, (item, &arr)) in items.iter().zip(&arrivals).enumerate() {
            let rec = match baseline {
                Baseline::CloudOnly => cloud_only::serve(&mut c, &mut vc, item, arr),
                Baseline::EdgeOnly => edge_only::serve(&mut c, &mut vc, item, arr),
                Baseline::PerLlm => perllm::serve(&mut c, &mut vc, item, arr),
            }
            .unwrap();
            let s = &new.records[i];
            assert_eq!(rec.tokens_out, s.tokens_out, "{policy:?} req {i}: tokens");
            assert_eq!(rec.bytes_up, s.bytes_up, "{policy:?} req {i}: bytes_up");
            assert_eq!(rec.bytes_down, s.bytes_down, "{policy:?} req {i}: bytes_down");
            assert_eq!(rec.t_done.to_bits(), s.t_done.to_bits(), "{policy:?} req {i}: t_done");
            assert_eq!(
                rec.latency_s.to_bits(),
                s.latency_s.to_bits(),
                "{policy:?} req {i}: latency"
            );
            assert_eq!(
                rec.prefill_s.to_bits(),
                s.prefill_s.to_bits(),
                "{policy:?} req {i}: prefill"
            );
            assert_eq!(
                rec.flops_edge.to_bits(),
                s.flops_edge.to_bits(),
                "{policy:?} req {i}: flops_edge"
            );
            assert_eq!(
                rec.flops_cloud.to_bits(),
                s.flops_cloud.to_bits(),
                "{policy:?} req {i}: flops_cloud"
            );
            assert_eq!(
                rec.mem_serving_gb.to_bits(),
                s.mem_serving_gb.to_bits(),
                "{policy:?} req {i}: mem_serving"
            );
            assert_eq!(
                rec.p_correct.to_bits(),
                s.p_correct.to_bits(),
                "{policy:?} req {i}: p_correct"
            );
            assert_eq!(rec.correct, s.correct, "{policy:?} req {i}: correct");
        }
        assert_eq!(new.uplink_bytes, vc.edges[0].link.uplink_bytes, "{policy:?}: uplink bytes");
        assert_eq!(
            new.downlink_bytes, vc.edges[0].link.downlink_bytes,
            "{policy:?}: downlink bytes"
        );
    }
}

/// Everything in an `ExecRecord` that must be bitwise-stable across the
/// constant-dynamics golden comparison (`correct` is excluded: its
/// Bernoulli draw consumes the coordinator's shared RNG, which advances
/// between the two serve calls; `p_correct` pins the quality instead).
fn assert_records_bitwise_equal(
    a: &msao::metrics::ExecRecord,
    b: &msao::metrics::ExecRecord,
    what: &str,
) {
    assert_eq!(a.tokens_out, b.tokens_out, "{what}: tokens_out");
    assert_eq!(a.accepted, b.accepted, "{what}: accepted");
    assert_eq!(a.proposed, b.proposed, "{what}: proposed");
    assert_eq!(a.offloads, b.offloads, "{what}: offloads");
    assert_eq!(a.replans, b.replans, "{what}: replans");
    assert_eq!(a.bytes_up, b.bytes_up, "{what}: bytes_up");
    assert_eq!(a.bytes_down, b.bytes_down, "{what}: bytes_down");
    assert_eq!(a.t_done.to_bits(), b.t_done.to_bits(), "{what}: t_done");
    assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits(), "{what}: latency");
    assert_eq!(a.prefill_s.to_bits(), b.prefill_s.to_bits(), "{what}: prefill");
    assert_eq!(a.flops_edge.to_bits(), b.flops_edge.to_bits(), "{what}: flops_edge");
    assert_eq!(a.flops_cloud.to_bits(), b.flops_cloud.to_bits(), "{what}: flops_cloud");
    assert_eq!(a.mem_serving_gb.to_bits(), b.mem_serving_gb.to_bits(), "{what}: mem_serving");
    assert_eq!(a.p_correct.to_bits(), b.p_correct.to_bits(), "{what}: p_correct");
    assert_eq!(a.faults, b.faults, "{what}: faults");
    assert_eq!(a.retries, b.retries, "{what}: retries");
    assert_eq!(a.failover, b.failover, "{what}: failover");
    assert_eq!(a.failed, b.failed, "{what}: failed");
}

#[test]
fn streaming_admission_reproduces_materialized_serve_bit_for_bit() {
    // The streaming-admission golden: `serve` builds sessions lazily at
    // their admission slot and folds them into records as they finish;
    // `serve_materialized_ref` keeps the pre-overhaul path (all
    // sessions up front, linear-scan scheduler). On the testbed trace
    // the two must agree on every record — times, bytes, flops,
    // quality — sequentially AND under the concurrent interleave, for
    // MSAO and a baseline.
    require_artifacts!();
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    for policy in [PolicyKind::Msao(Mode::Msao), PolicyKind::CloudOnly] {
        for conc in [1usize, 8] {
            let mut gen = Generator::new(31);
            let n = 6;
            let items = gen.items(Benchmark::Vqa, n);
            let arrivals = gen.arrivals(n, 2.5);
            let spec = TraceSpec::new(policy.clone())
                .trace(items, arrivals)
                .seed(5)
                .concurrency(conc);
            let golden = serve_materialized_ref(&mut c, &spec).unwrap();
            let streamed = serve(&mut c, &spec).unwrap();
            assert_eq!(streamed.records.len(), n);
            for (i, (a, b)) in golden.records.iter().zip(&streamed.records).enumerate() {
                assert_records_bitwise_equal(a, b, &format!("{policy:?} conc {conc} req {i}"));
            }
            assert_eq!(golden.uplink_bytes, streamed.uplink_bytes, "{policy:?}: uplink");
            assert_eq!(golden.downlink_bytes, streamed.downlink_bytes, "{policy:?}: downlink");
            assert_eq!(
                golden.batch_amortization.to_bits(),
                streamed.batch_amortization.to_bits(),
                "{policy:?} conc {conc}: amortization"
            );
            assert_eq!(
                golden.edge_wait_s.to_bits(),
                streamed.edge_wait_s.to_bits(),
                "{policy:?} conc {conc}: edge wait"
            );
        }
    }
}

#[test]
fn constant_network_trace_is_bit_for_bit_identical() {
    require_artifacts!();
    // Golden regression for the dynamic substrate: an explicit
    // constant-condition trace must reproduce the static link's serve()
    // outputs (times / bytes / quality) bit for bit — at concurrency 1
    // AND under the event-driven interleave — for MSAO and a baseline.
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let constant_trace = NetworkDynamics::Trace(vec![Segment {
        t_start: 0.0,
        bandwidth_mbps: 300.0,
        rtt_ms: c.cfg.network.rtt_ms,
    }]);
    for policy in [PolicyKind::Msao(Mode::Msao), PolicyKind::CloudOnly] {
        for conc in [1usize, 8] {
            let mut gen = Generator::new(31);
            let n = 6;
            let items = gen.items(Benchmark::Vqa, n);
            let arrivals = gen.arrivals(n, 2.5);
            let spec = TraceSpec::new(policy.clone())
                .trace(items, arrivals)
                .seed(5)
                .concurrency(conc);
            c.cfg.dynamics = NetworkDynamics::Constant;
            let golden = serve(&mut c, &spec).unwrap();
            c.cfg.dynamics = constant_trace.clone();
            let traced = serve(&mut c, &spec).unwrap();
            c.cfg.dynamics = NetworkDynamics::Constant;
            for (i, (a, b)) in golden.records.iter().zip(&traced.records).enumerate() {
                assert_records_bitwise_equal(a, b, &format!("{policy:?} conc {conc} req {i}"));
            }
            assert_eq!(golden.uplink_bytes, traced.uplink_bytes, "{policy:?}: uplink");
            assert_eq!(golden.downlink_bytes, traced.downlink_bytes, "{policy:?}: downlink");
            // The monitor never moved off the nominal prior on either run.
            assert_eq!(
                traced.net_estimate.bandwidth_mbps.to_bits(),
                (300.0f64).to_bits(),
                "{policy:?}: estimate drifted on a constant trace"
            );
        }
    }
}

#[test]
fn fleet_of_one_reproduces_single_edge_bit_for_bit() {
    require_artifacts!();
    // The fleet golden guarantee: an explicitly-configured fleet of one
    // edge must reproduce the fleet-less single-edge path (the
    // pre-refactor two-site testbed, itself pinned bit for bit to the
    // seed loops by the other golden tests) — times, bytes, flops,
    // quality — under every assignment strategy, at concurrency 1.
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let make_spec = |policy: PolicyKind, assign: Assign| {
        let mut gen = Generator::new(31);
        let n = 5;
        let items = gen.items(Benchmark::Vqa, n);
        let arrivals = gen.arrivals(n, 1.3);
        TraceSpec::new(policy).trace(items, arrivals).seed(5).concurrency(1).assign(assign)
    };
    for policy in [PolicyKind::Msao(Mode::Msao), PolicyKind::CloudOnly] {
        c.cfg.fleet = Vec::new();
        let golden = serve(&mut c, &make_spec(policy.clone(), Assign::RoundRobin)).unwrap();
        c.cfg.fleet = vec![EdgeSiteCfg {
            device: c.cfg.edge,
            network: c.cfg.network,
            dynamics: c.cfg.dynamics.clone(),
        }];
        for assign in [Assign::RoundRobin, Assign::LeastLoaded, Assign::Pinned(0)] {
            let res = serve(&mut c, &make_spec(policy.clone(), assign)).unwrap();
            for (i, (a, b)) in golden.records.iter().zip(&res.records).enumerate() {
                assert_records_bitwise_equal(a, b, &format!("{policy:?} {assign:?} req {i}"));
                assert_eq!(b.edge_id, 0, "{policy:?} {assign:?} req {i}: edge id");
            }
            assert_eq!(golden.uplink_bytes, res.uplink_bytes, "{policy:?} {assign:?}: uplink");
            assert_eq!(
                golden.downlink_bytes, res.downlink_bytes,
                "{policy:?} {assign:?}: downlink"
            );
            assert_eq!(
                golden.batch_amortization.to_bits(),
                res.batch_amortization.to_bits(),
                "{policy:?} {assign:?}: amortization"
            );
            assert_eq!(res.per_edge.len(), 1);
            assert_eq!(res.per_edge[0].requests, res.records.len());
            assert_eq!(
                golden.cloud_wait_s.to_bits(),
                res.cloud_wait_s.to_bits(),
                "{policy:?} {assign:?}: cloud wait"
            );
        }
        c.cfg.fleet = Vec::new();
    }
}

#[test]
fn sharded_serve_reproduces_sequential_bit_for_bit() {
    require_artifacts!();
    // The parallel-simulation golden: `--workers >= 2` routes the trace
    // through the sharded per-edge driver, which must reproduce the
    // sequential driver bit for bit — every record (times, bytes,
    // flops, quality), the fleet totals, the per-link breakdown, and
    // the event-sequence hash — on a heterogeneous fleet of three
    // (including a flaky Markov edge), across every assign strategy.
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let base = c.cfg.network;
    let mut mid = base;
    mid.bandwidth_mbps = 120.0;
    mid.rtt_ms = 40.0;
    c.cfg.fleet = vec![
        EdgeSiteCfg { device: c.cfg.edge, network: base, dynamics: NetworkDynamics::Constant },
        EdgeSiteCfg { device: c.cfg.edge, network: mid, dynamics: NetworkDynamics::Constant },
        EdgeSiteCfg {
            device: c.cfg.edge,
            network: base,
            dynamics: NetworkDynamics::Scenario(NetworkScenario::Flaky),
        },
    ];
    let make_spec = |assign: Assign, workers: usize| {
        let mut gen = Generator::new(33);
        let n = 6;
        let items = gen.items(Benchmark::Vqa, n);
        let arrivals = gen.arrivals(n, 2.5);
        TraceSpec::new(PolicyKind::Msao(Mode::Msao))
            .trace(items, arrivals)
            .seed(5)
            .concurrency(4)
            .assign(assign)
            .workers(workers)
    };
    for assign in [Assign::RoundRobin, Assign::LeastLoaded, Assign::Pinned(0)] {
        let golden = serve(&mut c, &make_spec(assign, 1)).unwrap();
        for workers in [2usize, 4] {
            let res = serve(&mut c, &make_spec(assign, workers)).unwrap();
            // Cheapest divergence detector first: the event-sequence
            // hash both drivers fold over every (request, time) step.
            assert_eq!(golden.events, res.events, "{assign:?} w{workers}: event count");
            assert_eq!(
                golden.events_hash, res.events_hash,
                "{assign:?} w{workers}: event-sequence hash"
            );
            for (i, (a, b)) in golden.records.iter().zip(&res.records).enumerate() {
                assert_records_bitwise_equal(a, b, &format!("{assign:?} w{workers} req {i}"));
                assert_eq!(a.edge_id, b.edge_id, "{assign:?} w{workers} req {i}: edge id");
            }
            assert_eq!(golden.uplink_bytes, res.uplink_bytes, "{assign:?} w{workers}: uplink");
            assert_eq!(
                golden.downlink_bytes, res.downlink_bytes,
                "{assign:?} w{workers}: downlink"
            );
            assert_eq!(
                golden.batch_amortization.to_bits(),
                res.batch_amortization.to_bits(),
                "{assign:?} w{workers}: amortization"
            );
            assert_eq!(
                golden.cloud_wait_s.to_bits(),
                res.cloud_wait_s.to_bits(),
                "{assign:?} w{workers}: cloud wait"
            );
            assert_eq!(
                golden.edge_wait_s.to_bits(),
                res.edge_wait_s.to_bits(),
                "{assign:?} w{workers}: edge wait"
            );
            for (ga, ra) in golden.per_edge.iter().zip(&res.per_edge) {
                let what = format!("{assign:?} w{workers} edge {}", ga.edge_id);
                assert_eq!(ga.requests, ra.requests, "{what}: requests");
                assert_eq!(ga.uplink_bytes, ra.uplink_bytes, "{what}: uplink");
                assert_eq!(ga.downlink_bytes, ra.downlink_bytes, "{what}: downlink");
                assert_eq!(
                    ga.net_estimate.bandwidth_mbps.to_bits(),
                    ra.net_estimate.bandwidth_mbps.to_bits(),
                    "{what}: bw estimate"
                );
            }
        }
    }
    c.cfg.fleet = Vec::new();
}

#[test]
fn least_loaded_shifts_traffic_off_the_weak_link() {
    require_artifacts!();
    // Heterogeneous mixed-link fleet (300/120/60 Mbps): the fleet-blind
    // round-robin split forces a third of the trace through the weak
    // link, while the monitor-driven least-loaded router reads each
    // edge's queue-wait/bandwidth beliefs and sends the weak edge
    // less — which is what shows up as a lower tail latency.
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let base = c.cfg.network;
    let mut mid = base;
    mid.bandwidth_mbps = 120.0;
    mid.rtt_ms = 40.0;
    let mut weak = base;
    weak.bandwidth_mbps = 60.0;
    weak.rtt_ms = 60.0;
    c.cfg.fleet = vec![
        EdgeSiteCfg { device: c.cfg.edge, network: base, dynamics: c.cfg.dynamics.clone() },
        EdgeSiteCfg { device: c.cfg.edge, network: mid, dynamics: c.cfg.dynamics.clone() },
        EdgeSiteCfg { device: c.cfg.edge, network: weak, dynamics: c.cfg.dynamics.clone() },
    ];
    let n = 12;
    let run = |c: &mut Coordinator, assign: Assign| {
        let mut gen = Generator::new(4242);
        let items = gen.items(Benchmark::Vqa, n);
        let arrivals = gen.arrivals(n, 5.4);
        let spec = TraceSpec::new(PolicyKind::Msao(Mode::Msao))
            .trace(items, arrivals)
            .seed(9)
            .concurrency(12)
            .assign(assign);
        serve(c, &spec).unwrap()
    };
    let rr = run(&mut c, Assign::RoundRobin);
    let ll = run(&mut c, Assign::LeastLoaded);
    c.cfg.fleet = Vec::new();
    assert_eq!(rr.per_edge[2].requests, n / 3, "round-robin must split evenly");
    assert!(
        ll.per_edge[2].requests < rr.per_edge[2].requests,
        "least-loaded sent {} of {n} requests to the weak link (round-robin: {})",
        ll.per_edge[2].requests,
        rr.per_edge[2].requests
    );
    let p99 = |r: &msao::coordinator::TraceResult| summarize(&r.records).latency_p99_s;
    assert!(
        p99(&ll) < p99(&rr),
        "least-loaded p99 {} must beat round-robin p99 {}",
        p99(&ll),
        p99(&rr)
    );
    // Every session completed on some edge of the fleet.
    assert_eq!(ll.per_edge.iter().map(|e| e.requests).sum::<usize>(), n);
}

#[test]
fn planner_repartitions_under_degraded_estimates() {
    require_artifacts!();
    // The planner consumes the monitor's belief: the same probed request
    // planned under a degraded link estimate must choose a different
    // partition (smaller uplink payload) than under the nominal one.
    let c = coord();
    let mut gen = Generator::new(7);
    let item = gen.vqa_item();
    let probe = run_probe(&c.eng, &c.cfg.msao, &item).unwrap();
    let plan_at = |net: NetEstimate| {
        plan(&PlanCtx {
            cfg: &c.cfg,
            item: &item,
            probe: &probe,
            net,
            p_conf: 0.7,
            n_out: 64,
            seed: 1,
        })
        .unwrap()
    };
    let nominal = plan_at(NetEstimate { bandwidth_mbps: 300.0, rtt_ms: 20.0 });
    let degraded = plan_at(NetEstimate { bandwidth_mbps: 20.0, rtt_ms: 100.0 });
    assert!(
        degraded.bytes_up < nominal.bytes_up,
        "degraded link must shrink the uplink partition: {} vs {}",
        degraded.bytes_up,
        nominal.bytes_up
    );
    // Both plans still honor the quality bound they were solved under.
    assert!(degraded.delta_q_est <= c.cfg.msao.epsilon_q + 1e-9);
}

#[test]
fn msao_replans_mid_trace_after_network_step_drop() {
    require_artifacts!();
    // The paper's adaptive claim, end to end: the link degrades (x0.2
    // bandwidth, x2 RTT) from t=0 while the monitor still believes the
    // nominal 300 Mbps. Request 0 is planned on the stale prior — its
    // coarse plan is byte-identical to the constant run — then the
    // estimate converges on real transfers and (a) the in-flight
    // speculative loop replans its draft length mid-stream, and (b)
    // later requests are planned against the degraded belief, provably
    // changing the partition.
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let n = 6;
    let run = |c: &mut Coordinator, dynamics: NetworkDynamics| {
        c.cfg.dynamics = dynamics;
        let mut gen = Generator::new(31);
        let items = gen.items(Benchmark::Vqa, n);
        let arrivals = gen.arrivals(n, 1.3);
        let spec = msao_spec(items, arrivals, Mode::Msao, 5).concurrency(1);
        let res = serve(c, &spec).unwrap();
        c.cfg.dynamics = NetworkDynamics::Constant;
        res
    };
    let constant = run(&mut c, NetworkDynamics::Constant);
    let degraded = run(
        &mut c,
        NetworkDynamics::Trace(vec![Segment {
            t_start: 0.0,
            bandwidth_mbps: 60.0,
            rtt_ms: 40.0,
        }]),
    );

    // (a) Request 0 planned before any observation: same coarse plan.
    assert_eq!(
        constant.records[0].bytes_up, degraded.records[0].bytes_up,
        "request 0 must plan on the prior belief"
    );
    // ...but its speculative loop noticed the drift mid-stream.
    assert!(
        degraded.records[0].replans > 0,
        "no mid-stream replan despite a 5x bandwidth drop"
    );
    assert_eq!(constant.records[0].replans, 0, "constant run must never replan");

    // (b) The monitor converged toward the truth (60 Mbps)...
    assert!(
        degraded.net_estimate.bandwidth_mbps < 150.0,
        "estimate stuck at {:.1} Mbps",
        degraded.net_estimate.bandwidth_mbps
    );
    // ...and at least one post-convergence request chose a different
    // partition than it did on the constant link.
    let repartitioned = (1..n)
        .any(|i| degraded.records[i].bytes_up != constant.records[i].bytes_up);
    assert!(repartitioned, "no request re-partitioned after convergence");
    // Latency reacts to the degraded link (sanity: the substrate bites).
    let sum_c = summarize(&constant.records);
    let sum_d = summarize(&degraded.records);
    assert!(sum_d.latency_mean_s > sum_c.latency_mean_s);
}

#[test]
fn scenario_flat_poisson_reproduces_serve_path_bit_for_bit() {
    require_artifacts!();
    // The scenario-subsystem golden: serving the compiled flat scenario
    // (`scenarios/flat.toml`: Poisson, default mix, no dialogue) must be
    // indistinguishable from the legacy `msao serve --mode msao` path —
    // every record (times, bytes, flops, quality), the link totals, and
    // the event-sequence hash, bit for bit.
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/scenarios/flat.toml");
    let scenario_spec = ScenarioSpec::load(path).unwrap().compile(42).unwrap();

    let mut gen = Generator::new(42);
    let items = gen.items(Benchmark::Vqa, 16);
    let arrivals = gen.arrivals(16, 2.0);
    let legacy_spec = msao_spec(items, arrivals, Mode::Msao, 42);

    let legacy = serve(&mut c, &legacy_spec).unwrap();
    let scenic = serve(&mut c, &scenario_spec).unwrap();
    assert_eq!(legacy.records.len(), scenic.records.len());
    for (i, (a, b)) in legacy.records.iter().zip(&scenic.records).enumerate() {
        assert_records_bitwise_equal(a, b, &format!("scenario req {i}"));
    }
    assert_eq!(legacy.events, scenic.events, "event count");
    assert_eq!(legacy.events_hash, scenic.events_hash, "event-sequence hash");
    assert_eq!(legacy.uplink_bytes, scenic.uplink_bytes, "uplink bytes");
    assert_eq!(legacy.downlink_bytes, scenic.downlink_bytes, "downlink bytes");
    assert_eq!(
        legacy.batch_amortization.to_bits(),
        scenic.batch_amortization.to_bits(),
        "amortization"
    );
}

#[test]
fn dialogue_scenario_serves_follow_up_turns_with_prefill_reuse() {
    require_artifacts!();
    // Multi-turn sessions end to end: every turn of the dialogue
    // scenario completes with causal times, follow-up turns exist, and
    // the reuse discount provably cuts total prefill time against the
    // identical trace re-served at discount 0 (concurrency 1 keeps the
    // two runs' transfer order — and hence every plan — identical, so
    // the only difference is the discounted prefill charge).
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/scenarios/dialogue.toml");
    let spec = ScenarioSpec::load(path).unwrap().compile(7).unwrap().concurrency(1);
    assert!(spec.reuse_discount > 0.0, "dialogue.toml must set a reuse discount");
    let follow_ups = spec.items.iter().filter(|i| i.prior_turns > 0).count();
    assert!(follow_ups > 0, "dialogue trace produced no follow-up turns");

    let discounted = serve(&mut c, &spec).unwrap();
    assert_eq!(discounted.records.len(), spec.items.len());
    for (i, r) in discounted.records.iter().enumerate() {
        assert!(r.tokens_out > 0, "turn {i} produced no tokens");
        assert!(r.t_done > r.t_arrival, "turn {i}: non-causal completion");
        assert!(r.latency_s.is_finite() && r.latency_s > 0.0, "turn {i}: latency");
    }

    let full = serve(&mut c, &spec.clone().reuse(0.0)).unwrap();
    let prefill = |res: &msao::coordinator::TraceResult| {
        res.records.iter().map(|r| r.prefill_s).sum::<f64>()
    };
    assert!(
        prefill(&discounted) < prefill(&full),
        "discount {} did not reduce prefill: {} vs {}",
        spec.reuse_discount,
        prefill(&discounted),
        prefill(&full)
    );
    // First turns never see the discount: their prefill charge matches
    // the undiscounted run bit for bit.
    for (i, (d, f)) in discounted.records.iter().zip(&full.records).enumerate() {
        if spec.items[i].prior_turns == 0 {
            assert_eq!(
                d.prefill_s.to_bits(),
                f.prefill_s.to_bits(),
                "first-turn req {i}: prefill must be identical"
            );
        }
    }
}

#[test]
fn fcfs_and_bare_deadlines_stay_bitwise_inert() {
    require_artifacts!();
    // The SLO golden: with `sched = fcfs` (default or explicit) and the
    // admission controller off, the SLO machinery must be invisible —
    // records and the event-sequence hash bit for bit identical to the
    // plain pre-SLO serve path, whether or not requests carry
    // deadlines, at concurrency {1, 8} x workers {1, 2}.
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    for conc in [1usize, 8] {
        for workers in [1usize, 2] {
            let make = || {
                let mut gen = Generator::new(31);
                let n = 6;
                let items = gen.items(Benchmark::Vqa, n);
                let arrivals = gen.arrivals(n, 2.5);
                msao_spec(items, arrivals, Mode::Msao, 5).concurrency(conc).workers(workers)
            };
            let golden = serve(&mut c, &make()).unwrap();
            let explicit = serve(&mut c, &make().sched(Sched::Fcfs)).unwrap();
            // Deadlines without EDF/admission only annotate records.
            let stamped =
                serve(&mut c, &make().slo_all(SloClass::LatencyCritical, 2.0)).unwrap();
            for (i, a) in golden.records.iter().enumerate() {
                let what = format!("conc {conc} w{workers} req {i}");
                assert_records_bitwise_equal(a, &explicit.records[i], &format!("fcfs {what}"));
                assert_records_bitwise_equal(a, &stamped.records[i], &format!("stamped {what}"));
            }
            assert_eq!(golden.events, explicit.events, "conc {conc} w{workers}: event count");
            assert_eq!(
                golden.events_hash, explicit.events_hash,
                "conc {conc} w{workers}: explicit-fcfs event hash"
            );
            assert_eq!(
                golden.events_hash, stamped.events_hash,
                "conc {conc} w{workers}: deadline-stamped event hash"
            );
            assert_eq!(stamped.shed, 0, "no admission control, nothing shed");
            assert_eq!(stamped.degraded, 0, "no admission control, nothing degraded");
            assert!(stamped.records.iter().all(|r| r.deadline_s == Some(2.0)));
            assert!(golden.records.iter().all(|r| r.deadline_s.is_none()));
        }
    }
}

#[test]
fn admission_sheds_best_effort_and_degrades_standard_under_overload() {
    require_artifacts!();
    // Burst arrivals with deadlines no schedule can meet (1 ms — below
    // the link RTT + payload transfer alone): the admission controller
    // must shed the best-effort third, degrade the standard third, and
    // leave the latency-critical third untouched; with the controller
    // off the same trace serves everything.
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let n = 12;
    let make = |admission: bool| {
        let mut gen = Generator::new(4242);
        let mut items = gen.items(Benchmark::Vqa, n);
        let arrivals: Vec<f64> = (0..n).map(|i| i as f64 * 0.01).collect();
        for (i, it) in items.iter_mut().enumerate() {
            it.slo = SloClass::ALL[i % 3];
            it.deadline_s = Some(0.001);
        }
        TraceSpec::new(PolicyKind::Msao(Mode::Msao))
            .trace(items, arrivals)
            .seed(9)
            .concurrency(4)
            .sched(Sched::Edf)
            .admission(admission)
    };
    let off = serve(&mut c, &make(false)).unwrap();
    assert_eq!(off.shed, 0, "controller off must never shed");
    assert_eq!(off.degraded, 0, "controller off must never degrade");
    assert!(off.records.iter().all(|r| r.tokens_out > 0));

    let on = serve(&mut c, &make(true)).unwrap();
    assert_eq!(on.records.len(), n, "shed requests still yield records");
    assert_eq!(on.shed, n / 3, "every best-effort request predicted to miss is shed");
    assert_eq!(on.degraded, n / 3, "every standard request predicted to miss degrades");
    for (i, r) in on.records.iter().enumerate() {
        match r.slo {
            SloClass::LatencyCritical => {
                assert!(!r.shed && !r.degraded, "req {i}: critical request shed/degraded")
            }
            SloClass::Standard => assert!(!r.shed, "req {i}: standard request shed"),
            SloClass::BestEffort => assert!(r.shed, "req {i}: best-effort request served"),
        }
        if r.shed {
            assert_eq!(r.tokens_out, 0, "req {i}: shed request produced tokens");
            assert_eq!(r.t_done.to_bits(), r.t_arrival.to_bits(), "req {i}: shed t_done");
        } else {
            assert!(r.tokens_out > 0, "req {i}: served request produced no tokens");
        }
    }
    // Degradation shrinks the decode budget, so the degraded run burns
    // strictly less compute per served request than the uncontrolled one.
    let served_flops = |res: &msao::coordinator::TraceResult| {
        res.records
            .iter()
            .filter(|r| !r.shed)
            .map(|r| r.flops_edge + r.flops_cloud)
            .sum::<f64>()
            / res.records.iter().filter(|r| !r.shed).count() as f64
    };
    assert!(
        served_flops(&on) < served_flops(&off),
        "degraded service level must cost less compute: {} vs {}",
        served_flops(&on),
        served_flops(&off)
    );
    let sum = summarize(&on.records);
    assert_eq!(sum.shed, n / 3);
    assert_eq!(sum.n, n);
}

#[test]
fn edf_without_deadlines_reproduces_fcfs_bit_for_bit() {
    require_artifacts!();
    // Deadline-free requests carry a +INF key component, which
    // `total_cmp`s Equal against every other +INF — so EDF with no
    // deadlines must fall through to the index tie-break and reproduce
    // FCFS bit for bit (records AND the event-sequence hash). With
    // deadlines, EDF is exercised end to end as a completion smoke:
    // every session still finishes with causal times.
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let n = 8;
    let make = |sched: Sched, deadlines: bool| {
        let mut gen = Generator::new(77);
        let mut items = gen.items(Benchmark::Vqa, n);
        let arrivals: Vec<f64> = (0..n).map(|i| i as f64 * 0.02).collect();
        if deadlines {
            for (i, it) in items.iter_mut().enumerate() {
                if i % 2 == 1 {
                    it.slo = SloClass::LatencyCritical;
                    it.deadline_s = Some(1.0);
                }
            }
        }
        TraceSpec::new(PolicyKind::Msao(Mode::Msao))
            .trace(items, arrivals)
            .seed(9)
            .concurrency(4)
            .sched(sched)
    };
    let fcfs = serve(&mut c, &make(Sched::Fcfs, false)).unwrap();
    let edf = serve(&mut c, &make(Sched::Edf, false)).unwrap();
    assert_eq!(fcfs.events, edf.events, "deadline-free EDF: event count");
    assert_eq!(fcfs.events_hash, edf.events_hash, "deadline-free EDF: event hash");
    for (i, (a, b)) in fcfs.records.iter().zip(&edf.records).enumerate() {
        assert_records_bitwise_equal(a, b, &format!("deadline-free EDF req {i}"));
    }

    let edf_dl = serve(&mut c, &make(Sched::Edf, true)).unwrap();
    assert_eq!(edf_dl.records.len(), n);
    for (i, r) in edf_dl.records.iter().enumerate() {
        assert!(r.tokens_out > 0, "req {i} produced no tokens");
        assert!(r.t_done > r.t_arrival, "req {i}: non-causal completion");
        assert_eq!(r.slo == SloClass::LatencyCritical, i % 2 == 1, "req {i}: class survives");
    }
}

#[test]
fn slo_scenario_file_compiles_and_serves_with_admission() {
    require_artifacts!();
    // scenarios/slo.toml end to end: the [slo] table's classes,
    // deadlines, EDF, and admission survive compile() and drive the
    // serving path; per-class accounting lands in the summary.
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/scenarios/slo.toml");
    let spec = ScenarioSpec::load(path).unwrap().compile(7).unwrap().concurrency(8);
    assert_eq!(spec.sched, Some(Sched::Edf));
    assert!(spec.admission);
    assert!(spec.items.iter().all(|i| i.deadline_s.is_some()));
    assert!(spec.items.iter().any(|i| i.slo == SloClass::LatencyCritical));
    let res = serve(&mut c, &spec).unwrap();
    assert_eq!(res.records.len(), spec.items.len());
    let sum = summarize(&res.records);
    assert!(sum.deadlined == res.records.len(), "every request carries a deadline");
    assert!((0.0..=1.0).contains(&sum.slo_attainment));
    for a in sum.slo_attainment_by_class {
        assert!((0.0..=1.0).contains(&a));
    }
    // Critical requests are never shed.
    for r in &res.records {
        if r.slo == SloClass::LatencyCritical {
            assert!(!r.shed);
        }
    }
}

#[test]
fn mixed_policy_trace_serves_heterogeneous_tenants() {
    require_artifacts!();
    // A PerRequest trace mixes MSAO and baseline sessions on one shared
    // cluster under the event-driven interleave: every session must
    // complete (starvation-free) with causal times, and per-tenant
    // signatures must survive (edge-only ships nothing up; cloud-only
    // ships raw payloads).
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let mut gen = Generator::new(55);
    let n = 8;
    let items = gen.items(Benchmark::Vqa, n);
    let arrivals: Vec<f64> = (0..n).map(|i| i as f64 * 0.05).collect();
    let policies: Vec<PolicyKind> = (0..n)
        .map(|i| match i % 4 {
            0 => PolicyKind::Msao(Mode::Msao),
            1 => PolicyKind::CloudOnly,
            2 => PolicyKind::EdgeOnly,
            _ => PolicyKind::PerLlm,
        })
        .collect();
    let spec = TraceSpec::new(PolicyKind::PerRequest(policies))
        .trace(items, arrivals)
        .seed(13)
        .concurrency(4);
    let res = serve(&mut c, &spec).unwrap();
    assert_eq!(res.records.len(), n);
    for (i, r) in res.records.iter().enumerate() {
        assert!(r.tokens_out > 0, "req {i} produced no tokens");
        assert!(r.t_done > r.t_arrival, "req {i}: non-causal completion");
        assert!(r.latency_s.is_finite() && r.latency_s > 0.0, "req {i}: latency");
    }
    for i in (2..n).step_by(4) {
        assert_eq!(res.records[i].bytes_up, 0, "edge-only req {i} used the uplink");
    }
    for i in (1..n).step_by(4) {
        assert!(res.records[i].bytes_up > 0, "cloud-only req {i} shipped nothing");
    }
}

#[test]
fn faults_disabled_is_bit_for_bit_inert() {
    require_artifacts!();
    // The fault-plane golden: with no [faults] table the plane is never
    // armed — no fault RNG streams exist, every record's fault fields
    // stay zero, and both serving drivers reproduce the pre-fault serve
    // path bit for bit at concurrency {1, 8} x workers {1, 2}.
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    for conc in [1usize, 8] {
        let make = || {
            let mut gen = Generator::new(31);
            let n = 6;
            let items = gen.items(Benchmark::Vqa, n);
            let arrivals = gen.arrivals(n, 2.5);
            msao_spec(items, arrivals, Mode::Msao, 5).concurrency(conc)
        };
        let golden = serve_materialized_ref(&mut c, &make()).unwrap();
        let sequential = serve(&mut c, &make().workers(1)).unwrap();
        for workers in [1usize, 2] {
            let res = serve(&mut c, &make().workers(workers)).unwrap();
            for (i, (a, b)) in golden.records.iter().zip(&res.records).enumerate() {
                assert_records_bitwise_equal(a, b, &format!("conc {conc} w{workers} req {i}"));
            }
            assert_eq!(
                sequential.events_hash, res.events_hash,
                "conc {conc} w{workers}: event-sequence hash"
            );
            assert_eq!(golden.uplink_bytes, res.uplink_bytes, "conc {conc} w{workers}: uplink");
            assert_eq!(res.failed, 0, "conc {conc} w{workers}: trace failed count");
            assert_eq!(res.failover, 0, "conc {conc} w{workers}: trace failover count");
            assert_eq!(res.retries, 0, "conc {conc} w{workers}: trace retry count");
            for (i, r) in res.records.iter().enumerate() {
                let what = format!("conc {conc} w{workers} req {i}");
                assert_eq!(r.faults, 0, "{what}: faults");
                assert_eq!(r.retries, 0, "{what}: retries");
                assert!(!r.failover, "{what}: failover");
                assert!(!r.failed, "{what}: failed");
            }
            let sum = summarize(&res.records);
            assert_eq!(sum.availability.to_bits(), (1.0f64).to_bits(), "conc {conc}: avail");
            assert_eq!(sum.retries_per_req, 0.0, "conc {conc}: retries/req");
            assert_eq!(sum.failover_rate, 0.0, "conc {conc}: failover rate");
            assert_eq!(sum.failed, 0, "conc {conc}: failed");
        }
    }
}

#[test]
fn certain_faults_pin_exact_retry_and_failover_counts() {
    require_artifacts!();
    // Deterministic fault arithmetic: p_fault = 1 faults every offload
    // attempt, so with max_retries = 2 each offloading request burns
    // exactly 3 attempts (initial + 2 retries) on its first transfer and
    // then exhausts recovery. MSAO fails over to edge-local decode and
    // still answers; Cloud-only (and PerLLM) fail the request outright;
    // Edge-only never touches the link and must not see the plane at all.
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let fc = FaultsCfg {
        p_fault: 1.0,
        jitter: 0.0,
        max_retries: 2,
        failover: true,
        ..FaultsCfg::default()
    };
    let n = 5;
    let make = |policy: PolicyKind| {
        let mut gen = Generator::new(31);
        let items = gen.items(Benchmark::Vqa, n);
        let arrivals = gen.arrivals(n, 1.3);
        TraceSpec::new(policy).trace(items, arrivals).seed(5).concurrency(4).faults(fc)
    };

    let msao = serve(&mut c, &make(PolicyKind::Msao(Mode::Msao))).unwrap();
    for (i, r) in msao.records.iter().enumerate() {
        assert_eq!(r.faults, 3, "msao req {i}: faults");
        assert_eq!(r.retries, 2, "msao req {i}: retries");
        assert!(r.failover, "msao req {i}: must fail over");
        assert!(!r.failed, "msao req {i}: failover still serves");
        assert!(r.tokens_out > 0, "msao req {i}: failover produced no tokens");
        assert!(r.t_done > r.t_arrival, "msao req {i}: non-causal completion");
    }
    let msao_sum = summarize(&msao.records);
    assert_eq!(msao_sum.availability.to_bits(), (1.0f64).to_bits(), "msao availability");
    assert_eq!(msao_sum.failover_rate.to_bits(), (1.0f64).to_bits(), "msao failover rate");
    assert_eq!(msao_sum.retries_per_req.to_bits(), (2.0f64).to_bits(), "msao retries/req");
    assert_eq!(msao_sum.failed, 0);

    for policy in [PolicyKind::CloudOnly, PolicyKind::PerLlm] {
        let res = serve(&mut c, &make(policy.clone())).unwrap();
        for (i, r) in res.records.iter().enumerate() {
            assert_eq!(r.faults, 3, "{policy:?} req {i}: faults");
            assert_eq!(r.retries, 2, "{policy:?} req {i}: retries");
            assert!(r.failed, "{policy:?} req {i}: must fail (no failover path)");
            assert!(!r.failover, "{policy:?} req {i}: baselines never fail over");
            assert_eq!(r.tokens_out, 0, "{policy:?} req {i}: failed request made tokens");
        }
        assert_eq!(res.failed, n, "{policy:?}: trace failed count");
        let sum = summarize(&res.records);
        assert_eq!(sum.availability.to_bits(), (0.0f64).to_bits(), "{policy:?} availability");
        assert_eq!(sum.failed, n, "{policy:?} summary failed");
    }

    let edge = serve(&mut c, &make(PolicyKind::EdgeOnly)).unwrap();
    for (i, r) in edge.records.iter().enumerate() {
        assert_eq!(r.faults, 0, "edge-only req {i}: faults");
        assert_eq!(r.retries, 0, "edge-only req {i}: retries");
        assert!(!r.failover && !r.failed, "edge-only req {i}: immune");
        assert!(r.tokens_out > 0, "edge-only req {i}: no tokens");
    }
}

#[test]
fn edge_only_tenants_are_bitwise_unaffected_by_faults() {
    require_artifacts!();
    // Fault isolation across tenants: a mixed trace alternates MSAO and
    // Edge-only on a round-robin fleet of two, so the Edge-only tenant
    // owns edge 1 and never touches a link or the cloud. Arming the
    // fault plane must reshape the MSAO records (edge 0) while leaving
    // every Edge-only record bit for bit identical.
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    c.cfg.fleet = vec![
        EdgeSiteCfg {
            device: c.cfg.edge,
            network: c.cfg.network,
            dynamics: c.cfg.dynamics.clone(),
        };
        2
    ];
    let n = 8;
    let make = |faults: Option<FaultsCfg>| {
        let mut gen = Generator::new(55);
        let items = gen.items(Benchmark::Vqa, n);
        let arrivals = gen.arrivals(n, 2.0);
        let policies: Vec<PolicyKind> = (0..n)
            .map(|i| {
                if i % 2 == 0 {
                    PolicyKind::Msao(Mode::Msao)
                } else {
                    PolicyKind::EdgeOnly
                }
            })
            .collect();
        let mut spec = TraceSpec::new(PolicyKind::PerRequest(policies))
            .trace(items, arrivals)
            .seed(13)
            .concurrency(n)
            .assign(Assign::RoundRobin);
        if let Some(fc) = faults {
            spec = spec.faults(fc);
        }
        spec
    };
    let calm = serve(&mut c, &make(None)).unwrap();
    let fc = FaultsCfg { p_fault: 0.5, max_retries: 1, failover: true, ..FaultsCfg::default() };
    let chaotic = serve(&mut c, &make(Some(fc))).unwrap();
    c.cfg.fleet = Vec::new();
    // The plane actually bit on the MSAO half (p = 0.5 over dozens of
    // transfers; deterministic under the fixed seed).
    let msao_faults: usize = chaotic.records.iter().step_by(2).map(|r| r.faults).sum();
    assert!(msao_faults > 0, "fault plane armed but nothing faulted");
    for i in (1..n).step_by(2) {
        let (a, b) = (&calm.records[i], &chaotic.records[i]);
        assert_eq!(a.edge_id, 1, "edge-only req {i} not on its own edge");
        assert_records_bitwise_equal(a, b, &format!("edge-only req {i}"));
        assert_eq!(b.faults, 0, "edge-only req {i}: faults");
    }
}

#[test]
fn sharded_serve_with_faults_reproduces_sequential_bit_for_bit() {
    require_artifacts!();
    // The determinism contract under fire: with the fault plane armed
    // (faults, timeouts, outages, retries, failovers all live) the
    // sharded driver must still reproduce the sequential driver bit for
    // bit at every worker count — records, fleet totals, and the
    // event-sequence hash. Retries are Local steps on the home shard,
    // so nothing about recovery may leak cross-shard ordering.
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let base = c.cfg.network;
    let mut mid = base;
    mid.bandwidth_mbps = 120.0;
    mid.rtt_ms = 40.0;
    c.cfg.fleet = vec![
        EdgeSiteCfg { device: c.cfg.edge, network: base, dynamics: NetworkDynamics::Constant },
        EdgeSiteCfg { device: c.cfg.edge, network: mid, dynamics: NetworkDynamics::Constant },
        EdgeSiteCfg {
            device: c.cfg.edge,
            network: base,
            dynamics: NetworkDynamics::Scenario(NetworkScenario::Flaky),
        },
    ];
    let fc = FaultsCfg {
        p_fault: 0.4,
        degraded_boost: 2.0,
        outage_gap_s: 4.0,
        outage_dur_s: 0.5,
        max_retries: 2,
        ..FaultsCfg::default()
    };
    let make = |workers: usize| {
        let mut gen = Generator::new(33);
        let n = 6;
        let items = gen.items(Benchmark::Vqa, n);
        let arrivals = gen.arrivals(n, 2.5);
        TraceSpec::new(PolicyKind::Msao(Mode::Msao))
            .trace(items, arrivals)
            .seed(5)
            .concurrency(4)
            .assign(Assign::RoundRobin)
            .workers(workers)
            .faults(fc)
    };
    let golden = serve(&mut c, &make(1)).unwrap();
    let total_faults: usize = golden.records.iter().map(|r| r.faults).sum();
    assert!(total_faults > 0, "fault plane armed but nothing faulted");
    for workers in [2usize, 4] {
        let res = serve(&mut c, &make(workers)).unwrap();
        assert_eq!(golden.events, res.events, "w{workers}: event count");
        assert_eq!(golden.events_hash, res.events_hash, "w{workers}: event-sequence hash");
        for (i, (a, b)) in golden.records.iter().zip(&res.records).enumerate() {
            assert_records_bitwise_equal(a, b, &format!("w{workers} req {i}"));
            assert_eq!(a.edge_id, b.edge_id, "w{workers} req {i}: edge id");
        }
        assert_eq!(golden.uplink_bytes, res.uplink_bytes, "w{workers}: uplink");
        assert_eq!(golden.downlink_bytes, res.downlink_bytes, "w{workers}: downlink");
        assert_eq!(golden.failed, res.failed, "w{workers}: failed count");
        assert_eq!(golden.failover, res.failover, "w{workers}: failover count");
        assert_eq!(golden.retries, res.retries, "w{workers}: retry count");
        assert_eq!(
            golden.cloud_wait_s.to_bits(),
            res.cloud_wait_s.to_bits(),
            "w{workers}: cloud wait"
        );
    }
    c.cfg.fleet = Vec::new();
}
