//! Integration tests over the full coordinator stack (real PJRT engines,
//! virtual testbed). One `Coordinator` is shared across tests via a
//! leaked singleton: engine startup (compile 11 graphs + calibration)
//! costs ~10 s and tests must not pay it repeatedly.

use std::sync::{Mutex, OnceLock};

use msao::baselines::{serve_trace_baseline, Baseline};
use msao::config::Config;
use msao::coordinator::mas::run_probe;
use msao::coordinator::planner::{plan, PlanCtx};
use msao::coordinator::{
    msao_testbed, serve_trace, serve_trace_concurrent, Batcher, Coordinator, Mode,
};
use msao::metrics::summarize;
use msao::sparsity::Modality;
use msao::workload::{Benchmark, Generator};

fn coord() -> std::sync::MutexGuard<'static, Coordinator> {
    static C: OnceLock<Mutex<Coordinator>> = OnceLock::new();
    C.get_or_init(|| {
        let mut cfg = Config::default();
        cfg.artifacts_dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
        Mutex::new(Coordinator::new(cfg).expect("run `make artifacts` first"))
    })
    // Poison-tolerant: one failing test must not cascade into the rest.
    .lock()
    .unwrap_or_else(|e| e.into_inner())
}

#[test]
fn probe_identifies_relevant_modality_and_salience() {
    let c = coord();
    let mut gen = Generator::new(5);
    let mut modal_hits = 0;
    let mut n = 0;
    for _ in 0..6 {
        let item = gen.mmbench_item();
        let probe = run_probe(&c.eng, &c.cfg.msao, &item).unwrap();
        let best = probe
            .mas
            .iter()
            .filter(|m| probe.present[m.modality.index()])
            .max_by(|a, b| a.beta.partial_cmp(&b.beta).unwrap())
            .unwrap();
        // Text questions always reference SOME modality; the probe's top
        // beta should usually be the ground-truth relevant one.
        if best.modality == item.relevant {
            modal_hits += 1;
        }
        n += 1;
        // Structural invariants.
        for m in &probe.mas {
            assert!((0.0..=1.0).contains(&m.mas));
        }
        if let Some(p) = &probe.pruned {
            assert!(p.count <= 192);
        }
    }
    assert!(modal_hits * 2 >= n, "modal probe hit {modal_hits}/{n}");
}

#[test]
fn probe_pruning_keeps_salient_patches() {
    let c = coord();
    let mut gen = Generator::new(6);
    let item = gen.vqa_item();
    let probe = run_probe(&c.eng, &c.cfg.msao, &item).unwrap();
    let p = probe.pruned.as_ref().unwrap();
    let sal = item.salient.as_ref().unwrap();
    let total_sal = sal.iter().filter(|&&s| s).count();
    let kept_sal = p.idx[..p.count]
        .iter()
        .filter(|&&i| i >= 0 && sal[i as usize])
        .count();
    // The trained spatial probe must retain nearly all salient patches.
    assert!(
        kept_sal as f64 >= 0.9 * total_sal as f64,
        "kept {kept_sal}/{total_sal} salient"
    );
    // And prune most of the background.
    let bg_total = 256 - total_sal;
    let bg_kept = p.count - kept_sal;
    assert!(
        (bg_kept as f64) < 0.3 * bg_total as f64,
        "kept {bg_kept}/{bg_total} background"
    );
}

#[test]
fn planner_respects_mas_floor_and_quality_bound() {
    let c = coord();
    let mut gen = Generator::new(7);
    let item = gen.vqa_item();
    let probe = run_probe(&c.eng, &c.cfg.msao, &item).unwrap();
    let p = plan(&PlanCtx {
        cfg: &c.cfg,
        item: &item,
        probe: &probe,
        p_conf: 0.7,
        n_out: 64,
        seed: 1,
    })
    .unwrap();
    // beta_m >= 1 - MAS_m (Eq. 11 last constraint).
    for m in [Modality::Image, Modality::Video, Modality::Audio] {
        if item.has(m) {
            let floor = 1.0 - probe.mas[m.index()].mas;
            assert!(
                p.beta[m.index()] >= floor - 1e-9,
                "{}: beta {} < floor {floor}",
                m.name(),
                p.beta[m.index()]
            );
        }
    }
    assert!(p.delta_q_est <= c.cfg.msao.epsilon_q + 1e-9, "dq {}", p.delta_q_est);
    assert!(p.n_draft >= 1 && p.n_draft <= c.cfg.msao.n_max);
    assert!(p.bytes_up > 0);
}

#[test]
fn msao_beats_cloud_only_latency_and_flops_under_load() {
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let mut gen = Generator::new(42);
    let items = gen.items(Benchmark::Vqa, 10);
    let arrivals = gen.arrivals(10, 1.3);
    let msao = summarize(
        &serve_trace(&mut c, &items, &arrivals, Mode::Msao, 1).unwrap().records,
    );
    let cloud = summarize(
        &serve_trace_baseline(&mut c, Baseline::CloudOnly, &items, &arrivals, 1)
            .unwrap()
            .records,
    );
    assert!(
        msao.latency_mean_s < cloud.latency_mean_s,
        "MSAO {} vs cloud {}",
        msao.latency_mean_s,
        cloud.latency_mean_s
    );
    assert!(msao.tflops_per_req < 0.7 * cloud.tflops_per_req);
    assert!(msao.throughput_tps > cloud.throughput_tps);
    // Speculation is actually happening.
    assert!(msao.acceptance_rate > 0.5, "acceptance {}", msao.acceptance_rate);
    assert!(msao.tokens_per_req > 32.0);
}

#[test]
fn ablations_degrade_the_right_metrics() {
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let mut gen = Generator::new(77);
    let items = gen.items(Benchmark::Vqa, 10);
    let arrivals = gen.arrivals(10, 1.3);
    let full = summarize(&serve_trace(&mut c, &items, &arrivals, Mode::Msao, 2).unwrap().records);
    let no_collab = summarize(
        &serve_trace(&mut c, &items, &arrivals, Mode::NoCollabSched, 2).unwrap().records,
    );
    let no_aware = summarize(
        &serve_trace(&mut c, &items, &arrivals, Mode::NoModalityAware, 2).unwrap().records,
    );
    // Static scheduling costs latency (Fig. 9 right).
    assert!(
        no_collab.latency_mean_s > 1.2 * full.latency_mean_s,
        "collab {} vs full {}",
        no_collab.latency_mean_s,
        full.latency_mean_s
    );
    // Uniform offloading ships more bytes and burns more compute.
    assert!(no_aware.gb_up_per_req > 1.5 * full.gb_up_per_req);
    assert!(no_aware.tflops_per_req > full.tflops_per_req);
}

#[test]
fn speculative_tokens_match_cloud_greedy_semantics() {
    // Spec decoding with greedy accept must produce tokens the full
    // model endorses: re-scoring the emitted prefix with the full model
    // must reproduce each committed token (verify-consistency).
    let mut c = coord();
    let eng_c = c.eng.c.clone();
    let mut gen = Generator::new(9);
    let items = gen.items(Benchmark::Vqa, 1);
    let res = serve_trace(&mut c, &items, &[0.0], Mode::Msao, 3).unwrap();
    let rec = &res.records[0];
    assert!(rec.tokens_out >= 32, "tokens {}", rec.tokens_out);
    assert!(rec.proposed > 0 && rec.accepted <= rec.proposed);
    assert!(rec.mem_edge_gb > 5.0); // weights resident at paper scale
    let _ = eng_c;
}

#[test]
fn scheduler_concurrency_one_reproduces_sequential_fcfs() {
    // The event-driven scheduler at concurrency 1 must reproduce the
    // seed's run-to-completion FCFS loop bit for bit: same tokens, same
    // virtual times, same quality, on an identically seeded testbed.
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let mut gen = Generator::new(31);
    let n = 6;
    let items = gen.items(Benchmark::Vqa, n);
    let arrivals = gen.arrivals(n, 1.3);
    let sched = serve_trace_concurrent(&mut c, &items, &arrivals, Mode::Msao, 5, 1).unwrap();

    // Seed FCFS reference: one request to completion at a time, sharing
    // testbed, batcher and theta exactly like the seed serve_trace did.
    let cfg = c.cfg.clone();
    let mut vc = msao_testbed(&cfg, 5);
    let mut batcher = Batcher::new(cfg.serve.batch_wait_ms, cfg.serve.verify_batch, true);
    let mut theta = c.theta();
    for (i, (item, &arr)) in items.iter().zip(&arrivals).enumerate() {
        let rec = c.serve(&mut vc, &mut batcher, &mut theta, item, arr, Mode::Msao).unwrap();
        let s = &sched.records[i];
        assert_eq!(rec.tokens_out, s.tokens_out, "req {i}: tokens");
        assert_eq!(rec.accepted, s.accepted, "req {i}: accepted");
        assert_eq!(rec.proposed, s.proposed, "req {i}: proposed");
        assert_eq!(rec.offloads, s.offloads, "req {i}: offloads");
        assert_eq!(rec.bytes_up, s.bytes_up, "req {i}: bytes_up");
        assert_eq!(rec.t_done.to_bits(), s.t_done.to_bits(), "req {i}: t_done");
        assert_eq!(rec.latency_s.to_bits(), s.latency_s.to_bits(), "req {i}: latency");
        assert_eq!(rec.prefill_s.to_bits(), s.prefill_s.to_bits(), "req {i}: prefill");
        assert_eq!(rec.p_correct.to_bits(), s.p_correct.to_bits(), "req {i}: p_correct");
    }
}

#[test]
fn cross_request_verify_batching_under_concurrent_load() {
    // With >= 8 sessions decoding at once, verify uplinks from different
    // requests interleave on the link and the dynamic batcher must
    // coalesce at least some of them — impossible for the seed's
    // run-to-completion loop, whose rounds are a full draft block apart.
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let mut gen = Generator::new(99);
    let n = 12;
    let items = gen.items(Benchmark::Vqa, n);
    // Burst arrivals: everything lands within ~100 ms.
    let arrivals: Vec<f64> = (0..n).map(|i| i as f64 * 0.01).collect();
    let res = serve_trace_concurrent(&mut c, &items, &arrivals, Mode::Msao, 7, 8).unwrap();
    assert!(
        res.batch_amortization > 0.0,
        "no cross-request piggyback (amortization {})",
        res.batch_amortization
    );
    assert!(res.records.iter().all(|r| r.tokens_out > 0));
}

#[test]
fn concurrent_poisson_trace_completes_every_session() {
    // No session starves under the event-driven interleave: every
    // request of a Poisson trace finishes with sane times and tokens.
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let mut gen = Generator::new(17);
    let n = 16;
    let items = gen.items(Benchmark::MmBench, n);
    let arrivals = gen.arrivals(n, 4.0);
    let res = serve_trace_concurrent(&mut c, &items, &arrivals, Mode::Msao, 11, 8).unwrap();
    assert_eq!(res.records.len(), n);
    for (i, r) in res.records.iter().enumerate() {
        assert!(r.tokens_out > 0, "req {i} produced no tokens");
        assert!(r.t_done > r.t_arrival, "req {i}: non-causal completion");
        assert!(r.latency_s.is_finite() && r.latency_s > 0.0, "req {i}: latency");
    }
}

#[test]
fn perllm_lands_between_edge_and_cloud_accuracy() {
    let mut c = coord();
    c.cfg.network.bandwidth_mbps = 300.0;
    let mut gen = Generator::new(123);
    let n = 14;
    let items = gen.items(Benchmark::Vqa, n);
    let arrivals = gen.arrivals(n, 1.3);
    let per = summarize(
        &serve_trace_baseline(&mut c, Baseline::PerLlm, &items, &arrivals, 4).unwrap().records,
    );
    // p_correct (not the sampled accuracy, which is noisy at n=14) must
    // sit between the edge and cloud capability anchors.
    let recs = serve_trace_baseline(&mut c, Baseline::PerLlm, &items, &arrivals, 4).unwrap();
    let mean_p: f64 = recs.records.iter().map(|r| r.p_correct).sum::<f64>() / n as f64;
    assert!(mean_p > 0.55 && mean_p < 0.80, "PerLLM mean p_correct {mean_p}");
    assert!(per.tflops_per_req > 0.0);
}
