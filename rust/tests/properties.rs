//! Hand-rolled property tests (offline environment: no proptest).
//! Each property runs hundreds of seeded random cases through the
//! deterministic PRNG; failures print the offending seed.

use msao::cluster::{DeviceSim, FaultPlane, Link, OutageProcess, SimModel, SystemMonitor};
use msao::config::{
    Config, DeviceCfg, EdgeSiteCfg, FaultsCfg, MsaoCfg, NetworkCfg, NetworkDynamics,
    NetworkScenario, Segment,
};
use msao::coordinator::scheduler::{
    drive, drive_linear_ref, drive_stream, SessionSource, StepOutcome,
};
use msao::coordinator::{
    drive_sharded, edge_seed, least_loaded, Batcher, CloudDevice, EdgeSite, Sequentialized,
    ShardedSource, Site, StepClass, VirtualCluster,
};
use msao::optimizer::{draft_len, expected_spec_len, linalg, Gp, Matern52, ThetaController};
use msao::scenario::{ArrivalProcess, DialogueCfg, MmppState, ScenarioSpec, Shape};
use msao::sparsity::{self, MasInputs, Modality};
use msao::util::json::Value;
use msao::util::stats::percentile;
use msao::util::Rng;
use msao::workload::{Benchmark, Generator};

fn cases(n: usize) -> impl Iterator<Item = u64> {
    (0..n as u64).map(|i| i * 0x9E3779B9 + 12345)
}

// --- MAS properties ---------------------------------------------------------

#[test]
fn prop_mas_always_in_unit_interval() {
    let cfg = MsaoCfg::default();
    for seed in cases(500) {
        let mut r = Rng::seed_from_u64(seed);
        let inp = MasInputs {
            beta: r.f64(),
            rho_spatial: r.f64(),
            gamma_avg: r.f64(),
        };
        let out = sparsity::mas(&cfg, Modality::Image, &inp);
        assert!(
            (0.0..=1.0).contains(&out.mas),
            "seed {seed}: MAS {} out of range for {inp:?}",
            out.mas
        );
    }
}

#[test]
fn prop_mas_monotone_in_relevance() {
    // Higher beta (more relevant) must never RAISE MAS (Eq. 7).
    let cfg = MsaoCfg::default();
    for seed in cases(300) {
        let mut r = Rng::seed_from_u64(seed);
        let rho = r.f64();
        let gam = r.f64();
        let b1 = r.f64();
        let b2 = r.f64();
        let (lo, hi) = if b1 < b2 { (b1, b2) } else { (b2, b1) };
        let m_lo = sparsity::mas(
            &cfg,
            Modality::Video,
            &MasInputs { beta: lo, rho_spatial: rho, gamma_avg: gam },
        );
        let m_hi = sparsity::mas(
            &cfg,
            Modality::Video,
            &MasInputs { beta: hi, rho_spatial: rho, gamma_avg: gam },
        );
        assert!(
            m_hi.mas <= m_lo.mas + 1e-12,
            "seed {seed}: beta {lo}->{hi} raised MAS {}->{}",
            m_lo.mas,
            m_hi.mas
        );
    }
}

#[test]
fn prop_masked_softmax_is_distribution_over_present() {
    for seed in cases(500) {
        let mut r = Rng::seed_from_u64(seed);
        let alpha: Vec<f32> = (0..4).map(|_| (r.f64() * 10.0 - 5.0) as f32).collect();
        let present: Vec<bool> = (0..4).map(|_| r.bool(0.6)).collect();
        let beta = sparsity::masked_softmax(&alpha, &present);
        let sum: f64 = beta.iter().sum();
        if present.iter().any(|&p| p) {
            assert!((sum - 1.0).abs() < 1e-9, "seed {seed}: sum {sum}");
        } else {
            assert_eq!(sum, 0.0);
        }
        for (b, &p) in beta.iter().zip(&present) {
            assert!(*b >= 0.0 && (p || *b == 0.0), "seed {seed}");
        }
    }
}

// --- spatial ratio ------------------------------------------------------------

#[test]
fn prop_spatial_ratio_monotone_in_threshold() {
    for seed in cases(200) {
        let mut r = Rng::seed_from_u64(seed);
        let imp: Vec<f32> = (0..64).map(|_| r.f64() as f32).collect();
        let t1 = r.f64();
        let t2 = r.f64();
        let (lo, hi) = if t1 < t2 { (t1, t2) } else { (t2, t1) };
        assert!(
            sparsity::spatial_ratio(&imp, lo) <= sparsity::spatial_ratio(&imp, hi),
            "seed {seed}"
        );
    }
}

// --- network / cost model ------------------------------------------------------

#[test]
fn prop_transfer_time_monotone_and_bounded() {
    for seed in cases(200) {
        let mut r = Rng::seed_from_u64(seed);
        let cfg = NetworkCfg {
            bandwidth_mbps: r.range_f64(50.0, 1000.0),
            rtt_ms: r.range_f64(1.0, 100.0),
            jitter: 0.0,
        };
        let mut link = Link::new(cfg, seed);
        let b1 = r.below(1_000_000) as u64;
        let b2 = b1 + r.below(1_000_000) as u64;
        let t1 = link.transfer_s(b1, msao::cluster::Dir::Up);
        let t2 = link.transfer_s(b2, msao::cluster::Dir::Up);
        assert!(t2 >= t1, "seed {seed}");
        assert!(t1 >= 0.5 * cfg.rtt_ms * 1e-3 - 1e-12, "seed {seed}: below propagation");
    }
}

#[test]
fn prop_constant_dynamics_bitwise_equal_static_link() {
    // The dynamic substrate's golden invariant: constant dynamics (and
    // an explicit one-segment trace carrying the base values) sample
    // bitwise-identical conditions to the static link at every time.
    for seed in cases(200) {
        let mut r = Rng::seed_from_u64(seed);
        let cfg = NetworkCfg {
            bandwidth_mbps: r.range_f64(50.0, 1000.0),
            rtt_ms: r.range_f64(1.0, 100.0),
            jitter: 0.0,
        };
        let mut plain = Link::new(cfg, seed);
        let mut traced = Link::with_dynamics(
            cfg,
            &NetworkDynamics::Trace(vec![Segment {
                t_start: 0.0,
                bandwidth_mbps: cfg.bandwidth_mbps,
                rtt_ms: cfg.rtt_ms,
            }]),
            seed,
        );
        for _ in 0..20 {
            let t = r.range_f64(0.0, 1e4);
            let bytes = r.below(10_000_000) as u64;
            assert_eq!(
                plain.serialize_s_at(t, bytes).to_bits(),
                traced.serialize_s_at(t, bytes).to_bits(),
                "seed {seed}"
            );
            assert_eq!(
                plain.one_way_s_at(t).to_bits(),
                traced.one_way_s_at(t).to_bits(),
                "seed {seed}"
            );
            assert_eq!(
                plain.serialize_s_at(t, bytes).to_bits(),
                plain.serialize_s(bytes).to_bits(),
                "seed {seed}: constant sampling must match base arithmetic"
            );
        }
    }
}

#[test]
fn prop_trace_lookup_returns_covering_segment() {
    for seed in cases(200) {
        let mut r = Rng::seed_from_u64(seed);
        let cfg = NetworkCfg { bandwidth_mbps: 300.0, rtt_ms: 20.0, jitter: 0.0 };
        // Random sorted trace with distinguishable per-segment values.
        let n = 1 + r.below(8);
        let mut t = r.range_f64(0.0, 5.0);
        let mut segs = Vec::new();
        for i in 0..n {
            segs.push(Segment {
                t_start: t,
                bandwidth_mbps: 100.0 + i as f64,
                rtt_ms: 10.0 + i as f64,
            });
            t += r.range_f64(0.1, 10.0);
        }
        let mut link = Link::with_dynamics(cfg, &NetworkDynamics::Trace(segs.clone()), seed);
        for _ in 0..50 {
            let q = r.range_f64(0.0, t + 10.0);
            let (bw, rtt) = link.conditions_at(q);
            // Reference: last segment with t_start <= q, else base.
            let want = segs.iter().rev().find(|s| s.t_start <= q);
            match want {
                Some(s) => assert_eq!((bw, rtt), (s.bandwidth_mbps, s.rtt_ms), "seed {seed}"),
                None => assert_eq!((bw, rtt), (cfg.bandwidth_mbps, cfg.rtt_ms), "seed {seed}"),
            }
        }
    }
}

#[test]
fn prop_markov_conditions_deterministic_positive_and_idempotent() {
    let cfg = NetworkCfg { bandwidth_mbps: 300.0, rtt_ms: 20.0, jitter: 0.0 };
    for seed in cases(50) {
        let dynamics = NetworkDynamics::Scenario(NetworkScenario::Flaky);
        let mut a = Link::with_dynamics(cfg, &dynamics, seed);
        let mut b = Link::with_dynamics(cfg, &dynamics, seed);
        let mut r = Rng::seed_from_u64(seed ^ 0xABCD);
        let queries: Vec<f64> = (0..40).map(|_| r.range_f64(0.0, 200.0)).collect();
        // b sees the same queries sorted — lazy extension must not
        // depend on query order.
        let answers_a: Vec<(f64, f64)> =
            queries.iter().map(|&t| a.conditions_at(t)).collect();
        for (&t, &want) in queries.iter().zip(&answers_a) {
            assert_eq!(a.conditions_at(t), want, "seed {seed}: idempotent");
        }
        let mut sorted = queries.clone();
        sorted.sort_by(f64::total_cmp);
        for &t in &sorted {
            let c = b.conditions_at(t);
            assert!(c.0 > 0.0 && c.1 > 0.0, "seed {seed}: non-positive conditions");
        }
        // Re-query original order against b: same sample path.
        for (&t, &want) in queries.iter().zip(&answers_a) {
            assert_eq!(b.conditions_at(t), want, "seed {seed}: order-dependent chain");
        }
    }
}

#[test]
fn prop_monitor_estimate_stays_within_observation_hull() {
    // The EMA estimate is a convex combination of the prior and the
    // observations, so it must stay inside their min/max hull.
    for seed in cases(200) {
        let mut r = Rng::seed_from_u64(seed);
        let cfg = NetworkCfg {
            bandwidth_mbps: r.range_f64(50.0, 1000.0),
            rtt_ms: r.range_f64(1.0, 100.0),
            jitter: 0.0,
        };
        let alpha = r.range_f64(0.05, 1.0);
        let mut m = SystemMonitor::new(&cfg, alpha);
        let (mut lo_bw, mut hi_bw) = (cfg.bandwidth_mbps, cfg.bandwidth_mbps);
        for _ in 0..100 {
            let bw = r.range_f64(10.0, 1200.0);
            lo_bw = lo_bw.min(bw);
            hi_bw = hi_bw.max(bw);
            m.observe_transfer(bw, r.range_f64(1.0, 200.0));
            let e = m.estimate();
            assert!(
                (lo_bw - 1e-9..=hi_bw + 1e-9).contains(&e.bandwidth_mbps),
                "seed {seed}: estimate {} outside [{lo_bw}, {hi_bw}]",
                e.bandwidth_mbps
            );
        }
    }
}

// --- fleet substrate / routing -------------------------------------------------

#[test]
fn prop_least_loaded_never_picks_a_dominated_edge() {
    // The fleet router's argmin score is strictly increasing in the
    // monitor's queue-wait and RTT beliefs and strictly decreasing in
    // its bandwidth belief, so the picked edge can never be strictly
    // dominated (higher wait, lower bandwidth, higher RTT) by another
    // edge — in particular never by an idle faster edge.
    for seed in cases(200) {
        let mut r = Rng::seed_from_u64(seed ^ 0x11AD);
        let k = 2 + r.below(5);
        let mut cfg = Config::default();
        cfg.replicate_edges(k).unwrap();
        let mut vc = VirtualCluster::new(&cfg, seed);
        for edge in &mut vc.edges {
            for _ in 0..r.below(6) {
                edge.monitor.observe_wait(Site::Edge(0), r.range_f64(0.0, 3.0));
            }
            for _ in 0..r.below(6) {
                edge.monitor.observe_transfer(r.range_f64(20.0, 600.0), r.range_f64(5.0, 120.0));
            }
        }
        let pick = least_loaded(&vc);
        let pw = vc.edges[pick].monitor.wait_s(Site::Edge(0));
        let pe = vc.edges[pick].monitor.estimate();
        for (i, e) in vc.edges.iter().enumerate() {
            if i == pick {
                continue;
            }
            let w = e.monitor.wait_s(Site::Edge(0));
            let est = e.monitor.estimate();
            let dominates =
                w < pw && est.bandwidth_mbps > pe.bandwidth_mbps && est.rtt_ms < pe.rtt_ms;
            assert!(
                !dominates,
                "seed {seed}: picked edge {pick} (wait {pw}, {pe:?}) but edge {i} \
                 strictly dominates (wait {w}, {est:?})"
            );
        }
    }
}

#[test]
fn prop_fleet_round_robin_equals_independent_single_edges_when_cloud_uncontended() {
    // Fleet-of-N with identical edges and a round-robin op split must
    // charge each edge exactly what N independent single-edge clusters
    // charge (bitwise), as long as the shared cloud never queues
    // cross-edge work. Each edge's ops live in a disjoint 1000 s window
    // to guarantee the uncontended premise; per-edge Flaky dynamics
    // exercise the per-edge seed derivation (fleet edge i == a lone
    // edge seeded with edge_seed(seed, i)).
    for seed in cases(25) {
        let mut r = Rng::seed_from_u64(seed ^ 0xF1EE7);
        let k = 2 + r.below(3);
        let mut cfg = Config::default();
        cfg.network.jitter = 0.0;
        cfg.dynamics = NetworkDynamics::Scenario(NetworkScenario::Flaky);
        cfg.fleet = vec![
            EdgeSiteCfg {
                device: cfg.edge,
                network: cfg.network,
                dynamics: cfg.dynamics.clone(),
            };
            k
        ];
        let mut fleet = VirtualCluster::new(&cfg, seed);
        let mut single_cfg = cfg.clone();
        single_cfg.fleet = Vec::new();
        let mut singles: Vec<VirtualCluster> =
            (0..k).map(|i| VirtualCluster::new(&single_cfg, edge_seed(seed, i))).collect();
        for i in 0..k {
            let mut t = 1000.0 * i as f64;
            for step in 0..20 {
                t += r.range_f64(0.01, 0.5);
                let secs = r.range_f64(0.001, 0.05);
                let bytes = r.below(1_000_000) as u64 + 1;
                let what = format!("seed {seed}: edge {i} step {step}");
                let a = fleet.exec(Site::Edge(i), t, secs, 1e9);
                let b = singles[i].exec(Site::Edge(0), t, secs, 1e9);
                assert_eq!(a.0.to_bits(), b.0.to_bits(), "{what}: exec start");
                assert_eq!(a.1.to_bits(), b.1.to_bits(), "{what}: exec end");
                let ua = fleet.send_up(i, a.1, bytes, false);
                let ub = singles[i].send_up(0, b.1, bytes, false);
                assert_eq!(ua.1.to_bits(), ub.1.to_bits(), "{what}: uplink arrival");
                let ca = fleet.exec(Site::Cloud, ua.1, secs, 2e9);
                let cb = singles[i].exec(Site::Cloud, ub.1, secs, 2e9);
                assert_eq!(ca.0.to_bits(), cb.0.to_bits(), "{what}: cloud start");
                let da = fleet.send_down(i, ca.1, 4096, false);
                let db = singles[i].send_down(0, cb.1, 4096, false);
                assert_eq!(da.1.to_bits(), db.1.to_bits(), "{what}: downlink arrival");
            }
        }
        // Per-edge metrics equal the independent runs.
        for i in 0..k {
            let (fe, se) = (&fleet.edges[i], &singles[i].edges[0]);
            assert_eq!(fe.flops.to_bits(), se.flops.to_bits(), "seed {seed}: edge {i} flops");
            assert_eq!(fe.link.uplink_bytes, se.link.uplink_bytes, "seed {seed}: edge {i} up");
            assert_eq!(
                fe.link.downlink_bytes, se.link.downlink_bytes,
                "seed {seed}: edge {i} down"
            );
            let (ea, eb) = (fe.monitor.estimate(), se.monitor.estimate());
            assert_eq!(
                ea.bandwidth_mbps.to_bits(),
                eb.bandwidth_mbps.to_bits(),
                "seed {seed}: edge {i} bw estimate"
            );
            assert_eq!(
                fe.monitor.wait_s(Site::Edge(0)).to_bits(),
                se.monitor.wait_s(Site::Edge(0)).to_bits(),
                "seed {seed}: edge {i} wait estimate"
            );
        }
        assert_eq!(
            fleet.cloud.flops.to_bits(),
            singles.iter().map(|s| s.cloud.flops).sum::<f64>().to_bits(),
            "seed {seed}: cloud flops must sum across the fleet"
        );
    }
}

#[test]
fn prop_exec_time_monotone_in_work() {
    for seed in cases(200) {
        let mut r = Rng::seed_from_u64(seed);
        let dev =
            DeviceSim::new(if r.bool(0.5) { DeviceCfg::a100() } else { DeviceCfg::rtx3090() });
        let m = if r.bool(0.5) { SimModel::qwen25vl_7b() } else { SimModel::qwen2vl_2b() };
        let s1 = r.range_f64(16.0, 2048.0);
        let s2 = s1 + r.range_f64(1.0, 1024.0);
        assert!(dev.prefill_s(&m, s2) >= dev.prefill_s(&m, s1), "seed {seed}");
        assert!(dev.decode_s(&m, s2) >= dev.decode_s(&m, s1), "seed {seed}");
    }
}

// --- scheduler -----------------------------------------------------------------

/// Mock session for scheduler equivalence: fixed event times, one step
/// each.
struct MockSession {
    times: Vec<f64>,
    at: usize,
}

impl MockSession {
    fn next_time(&self) -> f64 {
        self.times.get(self.at).copied().unwrap_or(f64::INFINITY)
    }

    fn step(&mut self) -> StepOutcome {
        self.at += 1;
        if self.at == self.times.len() {
            StepOutcome::Done
        } else {
            StepOutcome::Pending
        }
    }
}

/// Random Poisson trace: arrival-sorted sessions, 1-6 events each with
/// random inter-event gaps (including exact ties across sessions, which
/// a Poisson grid at coarse quantization produces).
fn poisson_mock_trace(r: &mut Rng, n: usize) -> Vec<Vec<f64>> {
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            t += r.exp(6.0);
            let steps = 1 + r.below(6);
            let mut times = Vec::with_capacity(steps);
            let mut tt = t;
            for _ in 0..steps {
                times.push(tt);
                // Coarse quantization manufactures cross-session ties so
                // the (time, index) tie-break is actually exercised.
                tt += (r.f64() * 8.0).round() * 0.125;
            }
            times
        })
        .collect()
}

struct MockStream<'a> {
    times: &'a [Vec<f64>],
    log: Vec<(usize, u64)>,
    live: usize,
    peak_live: usize,
}

impl SessionSource for MockStream<'_> {
    type Session = MockSession;

    fn admit(&mut self, i: usize) -> anyhow::Result<MockSession> {
        self.live += 1;
        self.peak_live = self.peak_live.max(self.live);
        Ok(MockSession { times: self.times[i].clone(), at: 0 })
    }

    fn next_time(&self, s: &MockSession) -> f64 {
        s.next_time()
    }

    fn step(&mut self, i: usize, s: &mut MockSession) -> anyhow::Result<StepOutcome> {
        self.log.push((i, s.next_time().to_bits()));
        Ok(s.step())
    }

    fn finish(&mut self, _i: usize, _s: MockSession) -> anyhow::Result<()> {
        self.live -= 1;
        Ok(())
    }
}

#[test]
fn prop_heap_scheduler_reproduces_linear_scan_step_sequence() {
    // The heap overhaul's pin: on random Poisson traces, at every cap,
    // the O(log n) heap loop and the O(n) linear-scan reference must
    // produce the exact same (session, event-time) step sequence — and
    // the streaming-admission driver the same again, with session
    // residency bounded by the cap.
    for seed in cases(60) {
        let mut r = Rng::seed_from_u64(seed ^ 0x5C4ED);
        let n = 5 + r.below(60);
        let trace = poisson_mock_trace(&mut r, n);
        for &cap in &[1usize, 4, 8, usize::MAX] {
            let mk = || -> Vec<MockSession> {
                trace.iter().map(|t| MockSession { times: t.clone(), at: 0 }).collect()
            };
            let mut heap_log: Vec<(usize, u64)> = Vec::new();
            let mut hs = mk();
            drive(&mut hs, cap, MockSession::next_time, |i, s| {
                heap_log.push((i, s.next_time().to_bits()));
                Ok(s.step())
            })
            .unwrap();
            let mut lin_log: Vec<(usize, u64)> = Vec::new();
            let mut ls = mk();
            drive_linear_ref(&mut ls, cap, MockSession::next_time, |i, s| {
                lin_log.push((i, s.next_time().to_bits()));
                Ok(s.step())
            })
            .unwrap();
            assert_eq!(heap_log, lin_log, "seed {seed} cap {cap}: heap diverged");
            let mut src = MockStream { times: &trace, log: Vec::new(), live: 0, peak_live: 0 };
            drive_stream(n, cap, &mut src).unwrap();
            assert_eq!(src.log, lin_log, "seed {seed} cap {cap}: streaming diverged");
            assert!(
                src.peak_live <= cap.min(n),
                "seed {seed} cap {cap}: residency {} over cap",
                src.peak_live
            );
            assert!(hs.iter().all(|s| s.at == s.times.len()), "seed {seed}: starved");
        }
    }
}

// --- sharded parallel driver ---------------------------------------------------

/// One request for the sharded-vs-sequential property: arrival, per-step
/// (service scale, class), home edge (`None` = routed by the first
/// Global step, LeastLoaded-style).
#[derive(Clone)]
struct ShardSpec {
    arrival: f64,
    steps: Vec<(f64, StepClass)>,
    route: Option<usize>,
}

struct TimelineShard {
    site: EdgeSite,
    id: usize,
}

struct TimelineSess {
    steps: Vec<(f64, StepClass)>,
    at: usize,
    t: f64,
    shard: usize,
    trace: Vec<u64>,
}

/// Real-timeline fleet under the sharded driver: Local steps sample the
/// edge's (per-edge-seeded, flaky Markov) link and charge the edge's
/// own device cursor through [`EdgeSite::exec`] — genuine shard-local
/// mutation including the lazy Markov chain extension — while Global
/// steps serialize on the shared [`CloudDevice`]. In LL mode the first
/// Global step routes by a cross-shard read (the edge cursors), which
/// only the windowed protocol orders correctly.
struct TimelineFleet {
    specs: Vec<ShardSpec>,
    shards: Vec<TimelineShard>,
    cloud: CloudDevice,
    ll: bool,
    finished: Vec<Option<Vec<u64>>>,
}

impl TimelineFleet {
    fn new(specs: Vec<ShardSpec>, k: usize, seed: u64, ll: bool) -> Self {
        let mut cfg = Config::default();
        cfg.network.jitter = 0.0;
        cfg.dynamics = NetworkDynamics::Scenario(NetworkScenario::Flaky);
        cfg.replicate_edges(k).unwrap();
        let vc = VirtualCluster::new(&cfg, seed);
        let finished = vec![None; specs.len()];
        TimelineFleet {
            specs,
            shards: vc
                .edges
                .into_iter()
                .enumerate()
                .map(|(id, site)| TimelineShard { site, id })
                .collect(),
            cloud: vc.cloud,
            ll,
            finished,
        }
    }

    fn fingerprint(&self) -> Vec<u64> {
        let mut out: Vec<u64> = self
            .shards
            .iter()
            .flat_map(|s| [s.site.busy_s().to_bits(), s.site.flops.to_bits()])
            .collect();
        out.push(self.cloud.busy_s().to_bits());
        out.push(self.cloud.flops.to_bits());
        for t in self.finished.iter().flatten() {
            out.extend_from_slice(t);
        }
        out
    }
}

impl ShardedSource for TimelineFleet {
    type Session = TimelineSess;
    type Shard = TimelineShard;

    fn n_shards(&self) -> usize {
        self.shards.len()
    }

    fn global_reads_shards(&self) -> bool {
        self.ll
    }

    fn admit(&mut self, i: usize) -> anyhow::Result<(TimelineSess, Option<usize>)> {
        let spec = self.specs[i].clone();
        let s = TimelineSess {
            steps: spec.steps,
            at: 0,
            t: spec.arrival,
            shard: spec.route.unwrap_or(0),
            trace: Vec::new(),
        };
        Ok((s, spec.route))
    }

    fn next_time(s: &TimelineSess) -> f64 {
        s.t
    }

    fn step_class(s: &TimelineSess) -> StepClass {
        s.steps[s.at].1
    }

    fn with_shards<R>(&mut self, f: impl FnOnce(&mut [TimelineShard]) -> R) -> R {
        f(&mut self.shards)
    }

    fn step_local(shard: &mut TimelineShard, s: &mut TimelineSess) -> anyhow::Result<StepOutcome> {
        let (scale, class) = s.steps[s.at];
        assert_eq!(class, StepClass::Local);
        // Service time depends on the edge's *sampled* link conditions:
        // the lazy Markov chain extends under the worker thread, and any
        // ordering divergence changes the bits downstream.
        let (bw, _rtt) = shard.site.link.conditions_at(s.t);
        let (start, end) = shard.site.exec(s.t, scale * 300.0 / bw, 1e9, shard.id);
        s.trace.push(start.to_bits());
        s.trace.push(end.to_bits());
        s.t = end;
        s.at += 1;
        assert!(s.at < s.steps.len(), "generator puts the Global completion step last");
        Ok(StepOutcome::Pending)
    }

    fn step_global(&mut self, _i: usize, s: &mut TimelineSess) -> anyhow::Result<StepOutcome> {
        let (service, class) = s.steps[s.at];
        assert_eq!(class, StepClass::Global);
        if self.ll && s.at == 0 {
            // LeastLoaded-style arrival routing: argmin over the edge
            // cursors — a cross-shard read at the arrival event.
            let mut pick = 0usize;
            for (e, sh) in self.shards.iter().enumerate() {
                if sh.site.busy_s() < self.shards[pick].site.busy_s() {
                    pick = e;
                }
            }
            s.shard = pick;
        }
        let (start, end) = self.cloud.exec(s.t, service, 2e9);
        s.trace.push(start.to_bits());
        s.trace.push(end.to_bits());
        s.t = end;
        s.at += 1;
        if s.at == s.steps.len() {
            Ok(StepOutcome::Done)
        } else {
            Ok(StepOutcome::Pending)
        }
    }

    fn shard_of(&self, s: &TimelineSess) -> usize {
        s.shard
    }

    fn finish(&mut self, i: usize, s: TimelineSess) -> anyhow::Result<()> {
        assert_eq!(s.at, s.steps.len(), "request {i} finished early");
        let mut trace = s.trace;
        trace.push(s.t.to_bits());
        self.finished[i] = Some(trace);
        Ok(())
    }
}

/// Random Poisson trace over the fleet. Route per the assign strategy:
/// 0 = pinned to one edge, 1 = round-robin, 2 = LL-style (unrouted,
/// first step Global). Coarse service quantization manufactures ties.
fn gen_shard_specs(r: &mut Rng, n: usize, k: usize, assign: usize) -> Vec<ShardSpec> {
    let pinned = r.below(k);
    let mut t = 0.0;
    (0..n)
        .map(|i| {
            t += (r.f64() * 8.0).round() * 0.125;
            let n_steps = 1 + r.below(4);
            let mut steps: Vec<(f64, StepClass)> = (0..n_steps)
                .map(|_| {
                    let service = 0.125 + (r.f64() * 4.0).round() * 0.125;
                    let class = if r.bool(0.5) { StepClass::Local } else { StepClass::Global };
                    (service, class)
                })
                .collect();
            // Completion must be Global (driver contract).
            steps.push((0.125 + (r.f64() * 4.0).round() * 0.125, StepClass::Global));
            let route = match assign {
                0 => Some(pinned),
                1 => Some(i % k),
                _ => {
                    steps[0].1 = StepClass::Global; // the routing step
                    None
                }
            };
            ShardSpec { arrival: t, steps, route }
        })
        .collect()
}

#[test]
fn prop_sharded_timeline_fleet_bitwise_equal_sequential() {
    // The tentpole pin at the timeline level: on random Poisson traces
    // over a fleet with per-edge flaky Markov links, the sharded driver
    // (workers 2 and 4) reproduces the sequential driver bit for bit —
    // edge cursors, FLOPs ledgers, Markov-dependent service times, and
    // every per-request event time — across pinned, round-robin, and
    // LeastLoaded-style routing.
    for seed in cases(12) {
        let mut r = Rng::seed_from_u64(seed ^ 0x44AD);
        let k = 2 + r.below(3);
        let n = 15 + r.below(30);
        for assign in 0..3usize {
            let specs = gen_shard_specs(&mut r, n, k, assign);
            for &cap in &[2usize, usize::MAX] {
                let mut oracle =
                    Sequentialized::new(TimelineFleet::new(specs.clone(), k, seed, assign == 2));
                drive_stream(n, cap, &mut oracle).unwrap();
                let oracle = oracle.into_inner();
                for &workers in &[2usize, 4] {
                    let mut par = TimelineFleet::new(specs.clone(), k, seed, assign == 2);
                    drive_sharded(n, cap, workers, &mut par).unwrap();
                    assert_eq!(
                        par.fingerprint(),
                        oracle.fingerprint(),
                        "seed {seed} assign {assign} cap {cap} workers {workers}: diverged"
                    );
                }
            }
        }
    }
}

// --- sharded real serve --------------------------------------------------------

/// Engine-backed suite below needs the AOT artifacts; without them it
/// self-skips (cleanly green) like the integration tests do.
fn serve_artifacts_built() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
}

/// Bitwise record equality for the sharded-vs-sequential serve pins.
/// Includes the sampled `correct` draw: per-session RNG streams are
/// salted from (trace seed, request index), so the quality draws must
/// survive any worker interleave too.
fn assert_serve_records_equal(a: &msao::metrics::ExecRecord, b: &msao::metrics::ExecRecord, what: &str) {
    assert_eq!(a.tokens_out, b.tokens_out, "{what}: tokens_out");
    assert_eq!(a.accepted, b.accepted, "{what}: accepted");
    assert_eq!(a.proposed, b.proposed, "{what}: proposed");
    assert_eq!(a.offloads, b.offloads, "{what}: offloads");
    assert_eq!(a.bytes_up, b.bytes_up, "{what}: bytes_up");
    assert_eq!(a.bytes_down, b.bytes_down, "{what}: bytes_down");
    assert_eq!(a.t_done.to_bits(), b.t_done.to_bits(), "{what}: t_done");
    assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits(), "{what}: latency");
    assert_eq!(a.prefill_s.to_bits(), b.prefill_s.to_bits(), "{what}: prefill");
    assert_eq!(a.flops_edge.to_bits(), b.flops_edge.to_bits(), "{what}: flops_edge");
    assert_eq!(a.flops_cloud.to_bits(), b.flops_cloud.to_bits(), "{what}: flops_cloud");
    assert_eq!(a.p_correct.to_bits(), b.p_correct.to_bits(), "{what}: p_correct");
    assert_eq!(a.correct, b.correct, "{what}: correct");
    assert_eq!(a.edge_id, b.edge_id, "{what}: edge_id");
    assert_eq!(a.shed, b.shed, "{what}: shed");
    assert_eq!(a.degraded, b.degraded, "{what}: degraded");
}

/// Heterogeneous fleet of four (300/120/60 Mbps constant + one flaky
/// Markov edge) shared by the real-serve sharding pins.
fn sharded_serve_coord() -> msao::coordinator::Coordinator {
    use msao::coordinator::Coordinator;
    let mut cfg = Config::default();
    cfg.artifacts_dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    cfg.network.bandwidth_mbps = 300.0;
    let base = cfg.network;
    let mut mid = base;
    mid.bandwidth_mbps = 120.0;
    mid.rtt_ms = 40.0;
    let mut weak = base;
    weak.bandwidth_mbps = 60.0;
    weak.rtt_ms = 60.0;
    cfg.fleet = vec![
        EdgeSiteCfg { device: cfg.edge, network: base, dynamics: NetworkDynamics::Constant },
        EdgeSiteCfg { device: cfg.edge, network: mid, dynamics: NetworkDynamics::Constant },
        EdgeSiteCfg { device: cfg.edge, network: weak, dynamics: NetworkDynamics::Constant },
        EdgeSiteCfg {
            device: cfg.edge,
            network: base,
            dynamics: NetworkDynamics::Scenario(NetworkScenario::Flaky),
        },
    ];
    Coordinator::new(cfg).expect("run `make artifacts` first")
}

#[test]
fn prop_sharded_real_serve_bitwise_equal_sequential() {
    // The tentpole pin on the REAL serve path: with per-session salted
    // RNG streams, per-edge theta/batcher state, and Local-classified
    // edge phases, `msao serve` through the sharded driver must
    // reproduce the sequential driver bit for bit — every record
    // (times, bytes, flops, quality draws), the fleet totals, and the
    // event-sequence hash — at workers {2, 4} x assign {RoundRobin,
    // LeastLoaded, Pinned} x concurrency {1, 8} on a heterogeneous
    // fleet of four including a flaky Markov edge.
    if !serve_artifacts_built() {
        eprintln!("skipped: artifacts/ not built (run `make artifacts`)");
        return;
    }
    use msao::coordinator::{serve, Assign, Mode, PolicyKind, TraceSpec};
    let c = sharded_serve_coord();
    let make = |assign: Assign, conc: usize, workers: usize| {
        let mut gen = Generator::new(71);
        let n = 8;
        let items = gen.items(Benchmark::Vqa, n);
        let arrivals = gen.arrivals(n, 3.0);
        TraceSpec::new(PolicyKind::Msao(Mode::Msao))
            .trace(items, arrivals)
            .seed(17)
            .concurrency(conc)
            .assign(assign)
            .workers(workers)
    };
    for assign in [Assign::RoundRobin, Assign::LeastLoaded, Assign::Pinned(1)] {
        for conc in [1usize, 8] {
            let golden = serve(&c, &make(assign, conc, 1)).unwrap();
            for workers in [2usize, 4] {
                let what = format!("{assign:?} conc {conc} w{workers}");
                let res = serve(&c, &make(assign, conc, workers)).unwrap();
                assert_eq!(golden.events, res.events, "{what}: event count");
                assert_eq!(golden.events_hash, res.events_hash, "{what}: event hash");
                assert_eq!(golden.records.len(), res.records.len(), "{what}: record count");
                for (i, (a, b)) in golden.records.iter().zip(&res.records).enumerate() {
                    assert_serve_records_equal(a, b, &format!("{what} req {i}"));
                }
                assert_eq!(golden.uplink_bytes, res.uplink_bytes, "{what}: uplink");
                assert_eq!(golden.downlink_bytes, res.downlink_bytes, "{what}: downlink");
                assert_eq!(
                    golden.batch_amortization.to_bits(),
                    res.batch_amortization.to_bits(),
                    "{what}: amortization"
                );
                assert_eq!(
                    golden.cloud_wait_s.to_bits(),
                    res.cloud_wait_s.to_bits(),
                    "{what}: cloud wait"
                );
                for (ga, ra) in golden.per_edge.iter().zip(&res.per_edge) {
                    assert_eq!(ga.requests, ra.requests, "{what} edge {}: requests", ga.edge_id);
                    assert_eq!(
                        ga.net_estimate.bandwidth_mbps.to_bits(),
                        ra.net_estimate.bandwidth_mbps.to_bits(),
                        "{what} edge {}: bw estimate",
                        ga.edge_id
                    );
                }
            }
        }
    }
}

#[test]
fn prop_sharded_real_serve_edf_admission_bitwise_equal_sequential() {
    // EDF + admission control under sharding: deadline-keyed event
    // ordering and the predictive admission decisions (shed / degrade)
    // are Global steps, so the sharded driver must reproduce them — and
    // everything downstream of them — bit for bit at workers {2, 4}.
    if !serve_artifacts_built() {
        eprintln!("skipped: artifacts/ not built (run `make artifacts`)");
        return;
    }
    use msao::coordinator::{serve, Mode, PolicyKind, Sched, SloClass, TraceSpec};
    let c = sharded_serve_coord();
    let make = |workers: usize| {
        let mut gen = Generator::new(4242);
        let n = 9;
        let mut items = gen.items(Benchmark::Vqa, n);
        let arrivals: Vec<f64> = (0..n).map(|i| i as f64 * 0.01).collect();
        for (i, it) in items.iter_mut().enumerate() {
            it.slo = SloClass::ALL[i % 3];
            it.deadline_s = Some(if i % 2 == 0 { 0.5 } else { 2.0 });
        }
        TraceSpec::new(PolicyKind::Msao(Mode::Msao))
            .trace(items, arrivals)
            .seed(29)
            .concurrency(4)
            .sched(Sched::Edf)
            .admission(true)
            .workers(workers)
    };
    let golden = serve(&c, &make(1)).unwrap();
    for workers in [2usize, 4] {
        let res = serve(&c, &make(workers)).unwrap();
        assert_eq!(golden.events, res.events, "w{workers}: event count");
        assert_eq!(golden.events_hash, res.events_hash, "w{workers}: event hash");
        assert_eq!(golden.shed, res.shed, "w{workers}: shed count");
        assert_eq!(golden.degraded, res.degraded, "w{workers}: degraded count");
        for (i, (a, b)) in golden.records.iter().zip(&res.records).enumerate() {
            assert_serve_records_equal(a, b, &format!("edf w{workers} req {i}"));
        }
    }
}

// --- optimizer -------------------------------------------------------------------

#[test]
fn prop_cholesky_reconstructs_spd_matrices() {
    for seed in cases(100) {
        let mut r = Rng::seed_from_u64(seed);
        let n = 2 + r.below(8);
        // SPD via A = B B^T + n*I.
        let b: Vec<f64> = (0..n * n).map(|_| r.normal()).collect();
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += b[i * n + k] * b[j * n + k];
                }
                a[i * n + j] = s + if i == j { n as f64 } else { 0.0 };
            }
        }
        let l = linalg::cholesky(&a, n).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += l[i * n + k] * l[j * n + k];
                }
                assert!((s - a[i * n + j]).abs() < 1e-8, "seed {seed} at ({i},{j})");
            }
        }
    }
}

#[test]
fn prop_gp_incremental_fit_matches_full_refit_posterior() {
    // The incremental-observe pin, end to end: a GP fitted by packed
    // row-appends (with the sticky jitter ladder) must predict the
    // exact same posterior — to the bit — as the old per-observation
    // full refit, rebuilt here on the full-layout linalg routines.
    // Duplicate inputs are injected to force jitter escalation.
    for seed in cases(40) {
        let mut r = Rng::seed_from_u64(seed ^ 0x6F17);
        let kernel = Matern52::default();
        // Zero noise makes duplicate inputs exactly singular, forcing
        // the jitter ladder; the noisy half covers the common path.
        let noise = if r.bool(0.5) { 0.0 } else { 1e-6 };
        let mut gp = Gp::new(Matern52::default(), noise);
        let n = 3 + r.below(12);
        let mut xs: Vec<Vec<f64>> = Vec::new();
        let mut ys: Vec<f64> = Vec::new();
        for _ in 0..n {
            let x = if !xs.is_empty() && r.bool(0.3) {
                xs[r.below(xs.len())].clone() // duplicate -> singular K
            } else {
                vec![r.f64(), r.f64()]
            };
            let y = r.normal();
            xs.push(x.clone());
            ys.push(y);
            gp.observe(x, y).unwrap();
        }

        // Old algorithm: full K with noise, jitter escalating from 0,
        // full-layout Cholesky, alpha against standardized outputs.
        let y_mean = ys.iter().sum::<f64>() / n as f64;
        let y_std = (ys.iter().map(|y| (y - y_mean).powi(2)).sum::<f64>() / n as f64)
            .sqrt()
            .max(1e-9);
        let ys_std: Vec<f64> = ys.iter().map(|y| (y - y_mean) / y_std).collect();
        let mut k = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..=i {
                let v = kernel.eval(&xs[i], &xs[j]);
                k[i * n + j] = v;
                k[j * n + i] = v;
            }
            k[i * n + i] += noise;
        }
        let mut jitter = 0.0;
        let chol = loop {
            let mut kj = k.clone();
            if jitter > 0.0 {
                for i in 0..n {
                    kj[i * n + i] += jitter;
                }
            }
            match linalg::cholesky(&kj, n) {
                Ok(l) => break l,
                Err(_) if jitter < 1.0 => {
                    jitter = if jitter == 0.0 { 1e-8 } else { jitter * 10.0 };
                }
                Err(e) => panic!("seed {seed}: reference refit failed: {e}"),
            }
        };
        let alpha = linalg::chol_solve(&chol, n, &ys_std);

        for q in 0..5 {
            let query = vec![r.f64(), r.f64()];
            let kx: Vec<f64> = xs.iter().map(|xi| kernel.eval(xi, &query)).collect();
            let mean_std: f64 = kx.iter().zip(&alpha).map(|(a, b)| a * b).sum();
            let v = linalg::solve_lower(&chol, n, &kx);
            let var_std =
                (kernel.eval(&query, &query) - v.iter().map(|a| a * a).sum::<f64>()).max(1e-12);
            let want = (mean_std * y_std + y_mean, var_std * y_std * y_std);
            let got = gp.predict(&query);
            assert_eq!(got.0.to_bits(), want.0.to_bits(), "seed {seed} q{q}: mean");
            assert_eq!(got.1.to_bits(), want.1.to_bits(), "seed {seed} q{q}: var");
        }
    }
}

#[test]
fn prop_gp_variance_nonnegative_and_shrinks_at_data() {
    for seed in cases(50) {
        let mut r = Rng::seed_from_u64(seed);
        let mut gp = Gp::new(Matern52::default(), 1e-6);
        let mut xs = Vec::new();
        for _ in 0..6 {
            let x = vec![r.f64(), r.f64()];
            gp.observe(x.clone(), r.normal()).unwrap();
            xs.push(x);
        }
        let mut v_at_data = 0.0f64;
        for x in &xs {
            let (_, v) = gp.predict(x);
            assert!(v >= 0.0, "seed {seed}: negative var {v}");
            v_at_data = v_at_data.max(v);
        }
        // Predictions are in raw output units, so compare relatively:
        // far from the data the posterior must be much less certain.
        let (_, v_far) = gp.predict(&[5.0, -3.0]);
        assert!(
            v_far > 10.0 * v_at_data.max(1e-12),
            "seed {seed}: far var {v_far} vs at-data {v_at_data}"
        );
    }
}

#[test]
fn prop_theta_controller_stays_in_bounds() {
    let cfg = MsaoCfg::default();
    for seed in cases(100) {
        let mut r = Rng::seed_from_u64(seed);
        let calib: Vec<f64> = (0..100).map(|_| r.f64() * 5.0).collect();
        let mut t = ThetaController::from_calibration(&cfg, &calib);
        let hmax = calib.iter().cloned().fold(0.0f64, f64::max);
        for _ in 0..200 {
            match r.below(3) {
                0 => t.record_entropy(r.f64() * 5.0),
                1 => t.on_verify(r.below(6), 5),
                _ => t.on_offload(),
            }
            assert!(
                (cfg.theta_min..=hmax.max(1.0) * 2.0).contains(&t.theta),
                "seed {seed}: theta {} escaped",
                t.theta
            );
        }
    }
}

#[test]
fn prop_spec_len_and_draft_len_sane() {
    for seed in cases(300) {
        let mut r = Rng::seed_from_u64(seed);
        let p = r.f64();
        let e = expected_spec_len(p, 5);
        assert!((1.0..=5.0).contains(&e), "seed {seed}: E[N] {e}");
        let d = draft_len(p, 0.8, 5);
        assert!((1..=5).contains(&d), "seed {seed}: N_draft {d}");
    }
}

// --- batcher ------------------------------------------------------------------

#[test]
fn prop_batcher_piggyback_fraction_bounded() {
    for seed in cases(100) {
        let mut r = Rng::seed_from_u64(seed);
        let mut b = Batcher::new(r.range_f64(0.5, 5.0), 1 + r.below(8), true);
        let mut t = 0.0;
        for _ in 0..200 {
            t += r.exp(100.0);
            b.admit(t);
        }
        let a = b.amortization();
        assert!((0.0..1.0).contains(&a), "seed {seed}: amortization {a}");
        assert_eq!(b.windows_opened + b.piggybacked, 200, "seed {seed}");
    }
}

// --- workload -----------------------------------------------------------------

#[test]
fn prop_items_well_formed() {
    for seed in cases(40) {
        let mut g = Generator::new(seed);
        for item in g.items(Benchmark::MmBench, 5) {
            assert!(item.has(item.relevant), "seed {seed}: relevant modality absent");
            if let (Some(v), Some(nv)) = (&item.video, &item.novel) {
                assert_eq!(v.len(), nv.len());
                assert!(nv[0], "seed {seed}: frame 0 must be novel");
            }
            if let Some(sal) = &item.salient {
                assert!(sal.iter().any(|&s| s), "seed {seed}: no salient patches");
            }
            assert!(!item.question.is_empty());
        }
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    for seed in cases(200) {
        let mut r = Rng::seed_from_u64(seed);
        let v = random_json(&mut r, 3);
        let text = v.to_string();
        let v2 = Value::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(v, v2, "seed {seed}");
    }
}

fn random_json(r: &mut Rng, depth: usize) -> Value {
    use std::collections::BTreeMap;
    match if depth == 0 { r.below(4) } else { r.below(6) } {
        0 => Value::Null,
        1 => Value::Bool(r.bool(0.5)),
        2 => Value::Num((r.normal() * 100.0 * 8.0).round() / 8.0),
        3 => {
            let n = r.below(8);
            Value::Str((0..n).map(|_| char::from(32 + r.below(90) as u8)).collect())
        }
        4 => Value::Arr((0..r.below(4)).map(|_| random_json(r, depth - 1)).collect()),
        _ => {
            let mut m = BTreeMap::new();
            for i in 0..r.below(4) {
                m.insert(format!("k{i}"), random_json(r, depth - 1));
            }
            Value::Obj(m)
        }
    }
}

// --- scenario -----------------------------------------------------------------

fn random_shape(r: &mut Rng) -> Shape {
    match r.below(4) {
        0 => Shape::None,
        1 => Shape::Ramp { to: r.range_f64(0.2, 6.0), duration_s: r.range_f64(0.5, 20.0) },
        2 => Shape::Spike {
            factor: r.range_f64(0.5, 8.0),
            t_start: r.range_f64(0.0, 5.0),
            duration_s: r.range_f64(0.2, 6.0),
        },
        _ => Shape::Diurnal {
            period_s: r.range_f64(1.0, 40.0),
            amplitude: r.range_f64(0.0, 0.95),
            phase: r.range_f64(0.0, 6.28),
        },
    }
}

fn random_arrival(r: &mut Rng, n: usize) -> ArrivalProcess {
    match r.below(3) {
        0 => ArrivalProcess::Poisson,
        1 => {
            let k = 1 + r.below(3);
            let states = (0..k)
                .map(|_| MmppState {
                    rate: r.range_f64(0.5, 12.0),
                    mean_dwell: r.range_f64(0.5, 8.0),
                })
                .collect();
            let transitions =
                (0..k).map(|_| (0..k).map(|_| r.f64() + 1e-3).collect()).collect();
            ArrivalProcess::Mmpp { states, transitions }
        }
        _ => {
            let mut t = 0.0;
            let times = (0..n)
                .map(|_| {
                    t += r.exp(2.0);
                    t
                })
                .collect();
            ArrivalProcess::Replay { times }
        }
    }
}

#[test]
fn prop_scenario_compile_times_finite_and_nondecreasing() {
    // Every (arrival process, shape, dialogue) combination must compile
    // to a well-formed trace: finite non-negative timestamps, sorted
    // arrivals, one arrival per item, at least one turn per session,
    // and `TraceSpec::validate` happy.
    for seed in cases(120) {
        let mut r = Rng::seed_from_u64(seed ^ 0x5CE2);
        let n = 1 + r.below(24);
        let sc = ScenarioSpec {
            n,
            rate: r.range_f64(0.3, 8.0),
            arrival: random_arrival(&mut r, n),
            shape: random_shape(&mut r),
            dialogue: if r.bool(0.4) {
                Some(DialogueCfg {
                    alpha: r.range_f64(1.05, 3.0),
                    max_turns: 1 + r.below(6),
                    think_mean_s: r.range_f64(0.1, 5.0),
                    reuse_discount: r.f64() * 0.9,
                })
            } else {
                None
            },
            ..Default::default()
        };
        let spec = sc.compile(seed).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        spec.validate().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(spec.items.len(), spec.arrivals.len(), "seed {seed}");
        assert!(spec.items.len() >= n, "seed {seed}: fewer items than sessions");
        assert!(
            spec.arrivals.iter().all(|t| t.is_finite() && *t >= 0.0),
            "seed {seed}: non-finite or negative arrival"
        );
        assert!(
            spec.arrivals.windows(2).all(|w| w[1] >= w[0]),
            "seed {seed}: arrivals out of order"
        );
    }
}

#[test]
fn prop_mmpp_single_state_bitwise_equals_poisson() {
    // The degenerate one-state chain must make no dwell or transition
    // draws: its stream is bit-for-bit the plain Poisson loop.
    for seed in cases(200) {
        let mut r = Rng::seed_from_u64(seed ^ 0x33A0);
        let rate = r.range_f64(0.2, 20.0);
        let dwell = r.range_f64(0.1, 50.0);
        let n = 1 + r.below(64);
        let p = ArrivalProcess::Mmpp {
            states: vec![MmppState { rate, mean_dwell: dwell }],
            transitions: vec![vec![1.0]],
        };
        let got = p.sample(&mut Generator::new(seed), n, 1.0).unwrap();
        let want = Generator::new(seed).arrivals(n, rate);
        assert_eq!(
            got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "seed {seed}: one-state MMPP diverged from Poisson"
        );
    }
}

#[test]
fn prop_identity_shape_and_flat_scenario_are_bitwise_poisson() {
    for seed in cases(100) {
        let mut r = Rng::seed_from_u64(seed ^ 0x1DE4);
        let n = 1 + r.below(40);
        let rate = r.range_f64(0.3, 6.0);
        // Shape::None must be an exact pass-through...
        let base = Generator::new(seed).arrivals(n, rate);
        let out = Shape::None.rescale(base.clone());
        assert_eq!(
            base.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            out.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "seed {seed}: Shape::None not identity"
        );
        // ...so a flat scenario reproduces the legacy generator stream.
        let sc = ScenarioSpec { n, rate, ..Default::default() };
        let spec = sc.compile(seed).unwrap();
        let mut gen = Generator::new(seed);
        let items = gen.items(Benchmark::Vqa, n);
        let want = gen.arrivals(n, rate);
        assert_eq!(
            spec.arrivals.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "seed {seed}: flat scenario arrivals diverge"
        );
        assert_eq!(spec.items.len(), items.len(), "seed {seed}");
        for (a, b) in spec.items.iter().zip(&items) {
            assert_eq!(a.id, b.id, "seed {seed}: item stream diverged");
        }
    }
}

#[test]
fn prop_shape_rescale_monotone_and_finite() {
    for seed in cases(150) {
        let mut r = Rng::seed_from_u64(seed ^ 0x54A9);
        let shape = random_shape(&mut r);
        shape.validate().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let base = Generator::new(seed).arrivals(1 + r.below(64), r.range_f64(0.5, 5.0));
        let out = shape.rescale(base);
        assert!(
            out.windows(2).all(|w| w[1] >= w[0]),
            "seed {seed}: {shape:?} broke arrival order"
        );
        assert!(out.iter().all(|t| t.is_finite() && *t >= 0.0), "seed {seed}: {shape:?}");
    }
}

#[test]
fn prop_generator_try_arrivals_rejects_degenerate_rates() {
    // Regression: these rates used to yield inf/NaN timestamps that
    // poisoned the event heap downstream; the fallible path rejects.
    for bad in [0.0, -1.0, f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
        assert!(Generator::new(1).try_arrivals(4, bad).is_err(), "rate {bad} must be rejected");
    }
    let ok = Generator::new(1).try_arrivals(4, 2.0).unwrap();
    assert_eq!(ok.len(), 4);
    assert!(ok.windows(2).all(|w| w[1] >= w[0]));
}

// --- fault plane ---------------------------------------------------------------

#[test]
fn prop_fault_draws_respect_probability_extremes() {
    // p = 0 must never fault and p = 1 must always fault, degraded or
    // not, for any seed — the boundary cases recovery logic leans on.
    for seed in cases(200) {
        let mut sure = FaultPlane::new(FaultsCfg { p_fault: 1.0, ..FaultsCfg::default() }, seed);
        let mut never = FaultPlane::new(FaultsCfg { p_fault: 0.0, ..FaultsCfg::default() }, seed);
        for i in 0..50 {
            let degraded = i % 2 == 0;
            assert!(sure.draw_fault(degraded), "seed {seed}: p=1 did not fault");
            assert!(!never.draw_fault(degraded), "seed {seed}: p=0 faulted");
        }
    }
}

#[test]
fn prop_backoff_bounded_by_cap_and_jitter() {
    // Every backoff delay sits in [min(cap, base*2^a), that * (1 +
    // jitter)]; with jitter 0 the schedule is exactly the capped
    // exponential, hence non-decreasing in the attempt index.
    for seed in cases(200) {
        let mut r = Rng::seed_from_u64(seed ^ 0xFA57);
        let cfg = FaultsCfg {
            backoff_base_s: r.range_f64(0.01, 0.2),
            backoff_cap_s: r.range_f64(0.5, 2.5),
            jitter: r.f64() * 0.5,
            ..FaultsCfg::default()
        };
        let mut fp = FaultPlane::new(cfg, seed);
        for attempt in 0..80 {
            let raw =
                (cfg.backoff_base_s * 2.0_f64.powi(attempt.min(60) as i32)).min(cfg.backoff_cap_s);
            let d = fp.backoff(attempt);
            assert!(d >= raw - 1e-12, "seed {seed} attempt {attempt}: {d} below {raw}");
            assert!(
                d <= raw * (1.0 + cfg.jitter) + 1e-12,
                "seed {seed} attempt {attempt}: {d} above jitter bound"
            );
        }
        let mut fp0 = FaultPlane::new(FaultsCfg { jitter: 0.0, ..cfg }, seed);
        let mut prev = 0.0;
        for attempt in 0..80 {
            let d = fp0.backoff(attempt);
            assert!(d >= prev, "seed {seed} attempt {attempt}: jitter-free backoff decreased");
            assert!(d <= cfg.backoff_cap_s + 1e-12, "seed {seed}: backoff over cap");
            prev = d;
        }
    }
}

#[test]
fn prop_outage_process_windows_are_causal_and_bounded() {
    // Scanning forward through the renewal process: every "down" answer
    // ends after the query time and within one window length of it,
    // re-querying the same instant is idempotent, and over a long
    // horizon the cloud is neither always down nor always up.
    for seed in cases(150) {
        let mut r = Rng::seed_from_u64(seed ^ 0x0D0A);
        let gap = r.range_f64(0.5, 5.0);
        let dur = r.range_f64(0.3, 2.0);
        let mut o = OutageProcess::new(gap, dur, seed);
        let (mut saw_down, mut saw_up) = (false, false);
        let mut t = 0.0;
        while t < 200.0 {
            let first = o.down_at(t);
            assert_eq!(first, o.down_at(t), "seed {seed}: down_at({t}) not idempotent");
            match first {
                Some(end) => {
                    saw_down = true;
                    assert!(end > t, "seed {seed}: outage ends at {end} <= query {t}");
                    assert!(end - t <= dur + 1e-9, "seed {seed}: residual exceeds window length");
                }
                None => saw_up = true,
            }
            t += 0.25;
        }
        assert!(
            saw_down && saw_up,
            "seed {seed}: degenerate process (gap {gap}, dur {dur}, down {saw_down}, up {saw_up})"
        );
    }
}

// --- stats ---------------------------------------------------------------------

#[test]
fn prop_percentile_within_minmax_and_monotone() {
    for seed in cases(200) {
        let mut r = Rng::seed_from_u64(seed);
        let xs: Vec<f64> = (0..1 + r.below(50)).map(|_| r.normal() * 10.0).collect();
        let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let q1 = r.f64();
        let q2 = r.f64();
        let (a, b) = if q1 < q2 { (q1, q2) } else { (q2, q1) };
        let p1 = percentile(&xs, a);
        let p2 = percentile(&xs, b);
        assert!(p1 >= lo - 1e-12 && p2 <= hi + 1e-12, "seed {seed}");
        assert!(p1 <= p2 + 1e-12, "seed {seed}");
    }
}
