"""L2 correctness: model graphs with Pallas kernels vs pure-jnp reference,
plus the structural invariants the rust coordinator relies on.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import dims, encoders, model, probe as probe_mod
from compile.dims import DRAFT, FULL, GEN_OFF, S_MAX, TEXT_OFF


@functools.lru_cache(maxsize=None)
def params(tag):
    key = jax.random.PRNGKey({"draft": 10, "full": 11}[tag])
    return model.init_params(key, {"draft": DRAFT, "full": FULL}[tag])


def make_inputs(seed=0, tlen=7, vlen=120, alen=0):
    r = np.random.default_rng(seed)
    text = np.full((dims.TEXT_SLOTS,), dims.PAD, np.int32)
    text[:tlen] = r.integers(0, 256, tlen)
    vis = r.standard_normal((dims.VIS_SLOTS, dims.D_ENC)).astype(np.float32)
    aud = r.standard_normal((dims.AUD_SLOTS, dims.D_ENC)).astype(np.float32)
    return (
        jnp.asarray(text),
        jnp.int32(tlen),
        jnp.asarray(vis),
        jnp.int32(vlen),
        jnp.asarray(aud),
        jnp.int32(alen),
    )


@pytest.mark.parametrize("tag,cfg", [("draft", DRAFT), ("full", FULL)])
def test_prefill_pallas_matches_ref(tag, cfg):
    p = params(tag)
    args = make_inputs()
    kv1, l1 = jax.jit(
        lambda *a: model.prefill(p, cfg, *a, use_pallas=True)
    )(*args)
    kv2, l2 = jax.jit(
        lambda *a: model.prefill(p, cfg, *a, use_pallas=False)
    )(*args)
    np.testing.assert_allclose(l1, l2, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(kv1, kv2, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("tag,cfg", [("draft", DRAFT), ("full", FULL)])
def test_decode_pallas_matches_ref(tag, cfg):
    p = params(tag)
    args = make_inputs()
    kv, _ = jax.jit(lambda *a: model.prefill(p, cfg, *a, use_pallas=False))(
        *args
    )
    toks = jnp.asarray([42], jnp.int32)
    lens = (args[3], args[5], args[1])
    l1, _ = model.block_decode(
        p, cfg, kv, jnp.int32(GEN_OFF), toks, *lens, use_pallas=True
    )
    l2, _ = model.block_decode(
        p, cfg, kv, jnp.int32(GEN_OFF), toks, *lens, use_pallas=False
    )
    np.testing.assert_allclose(l1, l2, rtol=2e-3, atol=2e-3)


def test_padding_content_does_not_change_logits():
    """Masking invariant: bytes in padded slots must be invisible."""
    p = params("draft")
    args = list(make_inputs(tlen=5, vlen=64))
    _, l1 = model.prefill(p, DRAFT, *args, use_pallas=False)
    # Scribble over padded text slots and padded vis rows.
    text = np.asarray(args[0]).copy()
    text[5:] = 99
    vis = np.asarray(args[2]).copy()
    vis[64:] = 123.0
    args[0] = jnp.asarray(text)
    args[2] = jnp.asarray(vis)
    _, l2 = model.prefill(p, DRAFT, *args, use_pallas=False)
    np.testing.assert_allclose(l1, l2, rtol=1e-5, atol=1e-5)


def test_decode_writes_only_its_slots():
    p = params("draft")
    args = make_inputs()
    kv, _ = model.prefill(p, DRAFT, *args, use_pallas=False)
    lens = (args[3], args[5], args[1])
    _, kv2 = model.block_decode(
        p, DRAFT, kv, jnp.int32(GEN_OFF), jnp.asarray([7], jnp.int32), *lens,
        use_pallas=False,
    )
    kv, kv2 = np.asarray(kv), np.asarray(kv2)
    # Everything except slot GEN_OFF is untouched.
    mask = np.ones(kv.shape, bool)
    mask[:, :, :, GEN_OFF] = False
    np.testing.assert_array_equal(kv[mask], kv2[mask])
    assert not np.allclose(kv[:, :, :, GEN_OFF], kv2[:, :, :, GEN_OFF])


def test_block_decode_equals_sequential_decode():
    """Verify semantics: scoring N tokens in one block must equal feeding
    them one by one — the property speculative verification depends on."""
    p = params("full")
    args = make_inputs(seed=3)
    lens = (args[3], args[5], args[1])
    kv0, _ = model.prefill(p, FULL, *args, use_pallas=False)

    toks = np.asarray([5, 17, 290, 31, 264, 112], np.int32)
    block_logits, _ = model.block_decode(
        p, FULL, kv0, jnp.int32(GEN_OFF), jnp.asarray(toks), *lens,
        use_pallas=False,
    )
    kv = kv0
    seq_logits = []
    for i, t in enumerate(toks):
        lg, kv = model.block_decode(
            p, FULL, kv, jnp.int32(GEN_OFF + i),
            jnp.asarray([t], jnp.int32), *lens, use_pallas=False,
        )
        seq_logits.append(np.asarray(lg[0]))
    np.testing.assert_allclose(
        np.asarray(block_logits), np.stack(seq_logits), rtol=1e-3, atol=1e-3
    )


def test_prefill_logits_depend_on_visual_tokens():
    p = params("draft")
    a1 = make_inputs(seed=1)
    a2 = list(a1)
    vis = np.asarray(a2[2]).copy()
    vis[:64] += 1.0
    a2[2] = jnp.asarray(vis)
    _, l1 = model.prefill(p, DRAFT, *a1, use_pallas=False)
    _, l2 = model.prefill(p, DRAFT, *a2, use_pallas=False)
    assert not np.allclose(np.asarray(l1), np.asarray(l2))


def test_vision_encoder_shapes_and_pallas_parity():
    vp = encoders.init_vision(jax.random.PRNGKey(7))
    patches = jnp.asarray(
        np.random.default_rng(0)
        .standard_normal((dims.N_PATCH, dims.PATCH_DIM))
        .astype(np.float32)
    )
    t1, t32_1, f1, p1 = encoders.vision_encode(vp, patches, use_pallas=True)
    t2, t32_2, f2, p2 = encoders.vision_encode(vp, patches, use_pallas=False)
    assert t1.shape == (dims.N_PATCH, dims.D_ENC)
    assert t32_1.shape == (dims.FRAME_TOK, dims.D_ENC)
    assert f1.shape == (dims.GRID, dims.GRID, dims.C_FEAT)
    assert p1.shape == (dims.D_ENC,)
    np.testing.assert_allclose(t1, t2, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(f1, f2, rtol=2e-3, atol=2e-3)


def test_probe_graphs_pallas_parity():
    pp = probe_mod.init_probe(jax.random.PRNGKey(8))
    r = np.random.default_rng(2)
    feat = jnp.asarray(
        r.standard_normal((dims.GRID, dims.GRID, dims.C_FEAT)), jnp.float32
    )
    np.testing.assert_allclose(
        probe_mod.probe_spatial(pp, feat, use_pallas=True),
        probe_mod.probe_spatial(pp, feat, use_pallas=False),
        rtol=1e-5, atol=1e-6,
    )
    frames = jnp.asarray(
        r.standard_normal((dims.N_FRAMES, dims.D_ENC)), jnp.float32
    )
    np.testing.assert_allclose(
        probe_mod.probe_temporal(pp, frames, use_pallas=True),
        probe_mod.probe_temporal(pp, frames, use_pallas=False),
        rtol=1e-6, atol=1e-7,
    )
    text = jnp.asarray(
        np.pad(r.integers(0, 256, 9), (0, dims.TEXT_SLOTS - 9)), jnp.int32
    )
    pooled = jnp.asarray(
        r.standard_normal((dims.N_MODALITIES, dims.D_ENC)), jnp.float32
    )
    np.testing.assert_allclose(
        probe_mod.probe_modal(pp, text, jnp.int32(9), pooled, use_pallas=True),
        probe_mod.probe_modal(pp, text, jnp.int32(9), pooled, use_pallas=False),
        rtol=1e-4, atol=1e-5,
    )


def test_probe_modal_prompt_masking():
    """Tokens past tlen must not influence the prompt embedding."""
    pp = probe_mod.init_probe(jax.random.PRNGKey(9))
    r = np.random.default_rng(3)
    pooled = jnp.asarray(
        r.standard_normal((dims.N_MODALITIES, dims.D_ENC)), jnp.float32
    )
    t1 = np.full((dims.TEXT_SLOTS,), 7, np.int32)
    t2 = t1.copy()
    t2[10:] = 200
    a1 = probe_mod.probe_modal(pp, jnp.asarray(t1), jnp.int32(10), pooled,
                               use_pallas=False)
    a2 = probe_mod.probe_modal(pp, jnp.asarray(t2), jnp.int32(10), pooled,
                               use_pallas=False)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=1e-6)
