"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes and value distributions; fixed cases pin the
edge conditions (empty selections, fully-masked rows, degenerate sizes).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import NEG, attention
from compile.kernels.lsh_probe import lsh_gamma
from compile.kernels.modal_probe import modal_scores
from compile.kernels.spatial_probe import spatial_probe
from compile.kernels.token_prune import token_prune

SETTINGS = dict(max_examples=15, deadline=None)


def rng(seed):
    return np.random.default_rng(seed)


# --- spatial probe ---------------------------------------------------------


@settings(**SETTINGS)
@given(
    g=st.sampled_from([4, 8, 16]),
    c=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_spatial_probe_matches_ref(g, c, seed):
    r = rng(seed)
    feat = jnp.asarray(r.standard_normal((g, g, c)), jnp.float32)
    w = jnp.asarray(r.standard_normal((c,)), jnp.float32)
    b = jnp.asarray(r.standard_normal((1,)), jnp.float32)
    got = spatial_probe(feat, w, b)
    want = ref.spatial_probe_ref(feat, w, b[0])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_spatial_probe_range():
    r = rng(0)
    feat = jnp.asarray(r.standard_normal((16, 16, 32)) * 10, jnp.float32)
    w = jnp.asarray(r.standard_normal((32,)), jnp.float32)
    m = spatial_probe(feat, w, jnp.zeros((1,), jnp.float32))
    assert float(m.min()) >= 0.0 and float(m.max()) <= 1.0


# --- LSH temporal probe ----------------------------------------------------


@settings(**SETTINGS)
@given(
    t=st.integers(2, 8),
    d=st.sampled_from([32, 64, 128]),
    k=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_lsh_gamma_matches_ref(t, d, k, seed):
    r = rng(seed)
    frames = jnp.asarray(r.standard_normal((t, d)), jnp.float32)
    proj = jnp.asarray(r.standard_normal((d, k)), jnp.float32)
    got = lsh_gamma(frames, proj)
    want = ref.lsh_gamma_ref(frames, proj)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


def test_lsh_identical_frames_are_fully_redundant():
    r = rng(1)
    f0 = r.standard_normal((1, 64)).astype(np.float32)
    frames = jnp.asarray(np.repeat(f0, 4, axis=0))
    proj = jnp.asarray(r.standard_normal((64, 32)), jnp.float32)
    gamma = np.asarray(lsh_gamma(frames, proj))
    assert gamma[0] == 1.0  # first frame always novel
    np.testing.assert_allclose(gamma[1:], 0.0, atol=1e-7)


def test_lsh_opposite_frames_are_novel():
    r = rng(2)
    f0 = r.standard_normal((64,)).astype(np.float32)
    frames = jnp.asarray(np.stack([f0, -f0]))
    proj = jnp.asarray(r.standard_normal((64, 32)), jnp.float32)
    gamma = np.asarray(lsh_gamma(frames, proj))
    # sign(r.f) != sign(-r.f) whenever r.f != 0 -> near-zero agreement.
    assert gamma[1] > 0.95


# --- modal probe -----------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.integers(1, 4),
    dp=st.sampled_from([32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_modal_scores_matches_ref(m, dp, seed):
    r = rng(seed)
    h = 48
    p = jnp.asarray(r.standard_normal((dp,)), jnp.float32)
    z = jnp.asarray(r.standard_normal((m, dp)), jnp.float32)
    w1 = jnp.asarray(r.standard_normal((2 * dp, h)) * 0.1, jnp.float32)
    b1 = jnp.asarray(r.standard_normal((h,)) * 0.1, jnp.float32)
    w2 = jnp.asarray(r.standard_normal((h,)) * 0.1, jnp.float32)
    b2 = jnp.asarray(r.standard_normal((1,)) * 0.1, jnp.float32)
    got = modal_scores(p, z, w1, b1, w2, b2)
    want = ref.modal_scores_ref(p, z, w1, b1, w2, b2[0])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


# --- attention -------------------------------------------------------------


@settings(**SETTINGS)
@given(
    h=st.sampled_from([1, 4]),
    sq=st.sampled_from([1, 6, 64, 96]),
    sk=st.sampled_from([64, 128, 352]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(h, sq, sk, seed):
    r = rng(seed)
    dh = 32
    q = jnp.asarray(r.standard_normal((h, sq, dh)), jnp.float32)
    k = jnp.asarray(r.standard_normal((h, sk, dh)), jnp.float32)
    v = jnp.asarray(r.standard_normal((h, sk, dh)), jnp.float32)
    # Random validity + causal-ish structure in the mask.
    valid = r.random((sk,)) < 0.8
    valid[0] = True  # at least one attendable slot
    mask = jnp.where(jnp.asarray(valid)[None, :], 0.0, NEG)
    mask = jnp.broadcast_to(mask, (sq, sk))
    bq = sq if sq < 48 else (48 if sq % 48 == 0 else 32)
    got = attention(q, k, v, mask, bq=bq, bk=32)
    want = ref.attention_ref(q, k, v, mask)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_attention_fully_masked_rows_match_ref():
    # With finite NEG a fully-masked row degrades to a uniform average in
    # both kernel and oracle; the model only ever reads valid rows, but the
    # two implementations must still agree bit-for-bit-ish here.
    r = rng(3)
    q = jnp.asarray(r.standard_normal((2, 32, 32)), jnp.float32)
    k = jnp.asarray(r.standard_normal((2, 64, 32)), jnp.float32)
    v = jnp.asarray(r.standard_normal((2, 64, 32)), jnp.float32)
    mask = jnp.full((32, 64), NEG)
    got = np.asarray(attention(q, k, v, mask, bq=32, bk=32))
    want = np.asarray(ref.attention_ref(q, k, v, mask))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_attention_is_row_softmax_convex_combination():
    r = rng(4)
    q = jnp.asarray(r.standard_normal((1, 32, 32)), jnp.float32)
    k = jnp.asarray(r.standard_normal((1, 64, 32)), jnp.float32)
    v = jnp.ones((1, 64, 32), jnp.float32)
    mask = jnp.zeros((32, 64))
    out = np.asarray(attention(q, k, v, mask, bq=32, bk=32))
    np.testing.assert_allclose(out, 1.0, rtol=1e-5)  # convex comb of ones


# --- token prune -----------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.sampled_from([16, 64, 256]),
    keep_frac=st.floats(0.1, 1.0),
    tau=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_token_prune_matches_ref(n, keep_frac, tau, seed):
    r = rng(seed)
    d = 16
    keep = max(1, int(n * keep_frac))
    tokens = jnp.asarray(r.standard_normal((n, d)), jnp.float32)
    imp = jnp.asarray(r.random((n,)), jnp.float32)
    tau_a = jnp.asarray([tau], jnp.float32)
    got_o, got_i, got_c = token_prune(tokens, imp, tau_a, keep)
    want_o, want_i, want_c = ref.token_prune_ref(tokens, imp, tau, keep)
    np.testing.assert_allclose(got_o, want_o)
    np.testing.assert_array_equal(got_i, want_i)
    assert int(got_c[0]) == int(want_c)


def test_token_prune_none_selected():
    tokens = jnp.ones((32, 8), jnp.float32)
    imp = jnp.zeros((32,), jnp.float32)
    out, idx, cnt = token_prune(tokens, imp, jnp.asarray([0.5], jnp.float32), 16)
    assert int(cnt[0]) == 0
    np.testing.assert_allclose(out, 0.0)
    assert int(np.asarray(idx).max()) == -1


def test_token_prune_all_selected_capped():
    tokens = jnp.arange(32 * 4, dtype=jnp.float32).reshape(32, 4)
    imp = jnp.ones((32,), jnp.float32)
    out, idx, cnt = token_prune(tokens, imp, jnp.asarray([0.5], jnp.float32), 8)
    assert int(cnt[0]) == 8
    np.testing.assert_array_equal(np.asarray(idx), np.arange(8))
    np.testing.assert_allclose(out, np.asarray(tokens)[:8])


def test_token_prune_order_preserving():
    r = rng(5)
    tokens = jnp.asarray(r.standard_normal((64, 4)), jnp.float32)
    imp = jnp.asarray(r.random((64,)), jnp.float32)
    _, idx, cnt = token_prune(tokens, imp, jnp.asarray([0.6], jnp.float32), 32)
    idx = np.asarray(idx)[: int(cnt[0])]
    assert (np.diff(idx) > 0).all()
