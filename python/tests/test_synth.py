"""Synthetic distribution contract tests (synth.py <-> rust generator).

These pin the statistical properties both sides rely on: salient patches
carry higher energy, static frames drift slightly, templates reference
the right modality keywords.
"""

import numpy as np

from compile import synth
from compile.dims import N_PATCH, PATCH_DIM, TEXT_SLOTS


def test_image_salience_energy_gap():
    rng = np.random.default_rng(0)
    for _ in range(5):
        patches, mask = synth.make_image(rng)
        assert patches.shape == (N_PATCH, PATCH_DIM)
        e = (patches**2).mean(axis=1)
        assert e[mask].mean() > 5 * e[~mask].mean()


def test_video_static_frames_are_near_duplicates():
    rng = np.random.default_rng(1)
    frames, novel = synth.make_video(rng, 8, p_static=0.5)
    assert novel[0]
    for t in range(1, 8):
        d = np.abs(frames[t] - frames[t - 1]).mean()
        if novel[t]:
            assert d > 0.3
        else:
            assert d < 0.1


def test_questions_reference_modality_keywords():
    rng = np.random.default_rng(2)
    keywords = ["word", "ima", "vid", "aud"]  # loose per-modality markers
    hits = 0
    for m in range(4):
        toks, tlen = synth.make_question(rng, m)
        assert toks.shape == (TEXT_SLOTS,)
        text = bytes(int(t) for t in toks[1 : tlen - 1]).decode()
        # Each class template mentions its modality family.
        families = [
            ["word", "phrase", "term"],
            ["picture", "image", "object", "color", "shape"],
            ["video", "clip", "frames", "motion", "moves"],
            ["sound", "audio", "speaker", "recording", "heard"],
        ]
        if any(k in text for k in families[m]):
            hits += 1
    assert hits == 4
    del keywords


def test_audio_shape_and_finite():
    rng = np.random.default_rng(3)
    a = synth.make_audio(rng)
    assert a.shape == (32, 80)
    assert np.isfinite(a).all()
