"""AOT artifact smoke: manifest consistency and HLO presence.

Skipped when artifacts/ has not been built (run `make artifacts` first);
the Makefile always builds artifacts before pytest.
"""

import json
import os
import zipfile

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built",
)


def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


EXPECTED_GRAPHS = {
    "vision_encoder", "audio_encoder", "probe_spatial", "probe_temporal",
    "probe_modal", "prune_tokens", "draft_prefill", "draft_decode",
    "full_prefill", "full_decode", "full_verify",
}


def test_all_graphs_present():
    m = manifest()
    assert set(m["graphs"].keys()) == EXPECTED_GRAPHS
    for g in m["graphs"].values():
        path = os.path.join(ART, g["file"])
        assert os.path.exists(path), g["file"]
        head = open(path).read(200)
        assert "HloModule" in head


def test_weight_groups_match_npz():
    m = manifest()
    for group, info in m["weights"].items():
        path = os.path.join(ART, info["file"])
        with zipfile.ZipFile(path) as z:
            names = {n.removesuffix(".npy") for n in z.namelist()}
        assert names == set(info["names"]), group


def test_graph_weight_counts():
    m = manifest()
    for name, g in m["graphs"].items():
        if g["weights"] is None:
            assert g["n_weight_args"] == 0
        else:
            assert g["n_weight_args"] == len(m["weights"][g["weights"]]["names"]), name


def test_kv_shapes_consistent():
    m = manifest()
    c = m["constants"]
    kv_draft = m["graphs"]["draft_decode"]["inputs"][0]["shape"]
    assert kv_draft == [
        c["DRAFT_LAYERS"], 2, c["DRAFT_HEADS"], c["S_MAX"], c["DH"]
    ]
    kv_full = m["graphs"]["full_verify"]["inputs"][0]["shape"]
    assert kv_full == [
        c["FULL_LAYERS"], 2, c["FULL_HEADS"], c["S_MAX"], c["DH"]
    ]
    # decode outputs: logits then kv, same kv shape in/out
    outs = m["graphs"]["full_verify"]["outputs"]
    assert outs[0]["shape"] == [c["N_SPEC"], c["VOCAB"]]
    assert outs[1]["shape"] == kv_full


def test_weights_are_finite():
    m = manifest()
    for group, info in m["weights"].items():
        with np.load(os.path.join(ART, info["file"])) as z:
            for n in z.files:
                assert np.isfinite(z[n]).all(), f"{group}:{n}"
