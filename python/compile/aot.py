"""AOT driver: lower every L2 graph to HLO text + weight npz + manifest.

Run once at build time (`make artifacts`); python never appears on the
rust request path. Interchange is HLO *text*, not serialized
HloModuleProto — the image's xla_extension 0.5.1 rejects jax>=0.5 protos
with 64-bit instruction ids; the text parser reassigns ids cleanly
(see /opt/xla-example/README.md).

Weights are NOT baked into the HLO as constants: each graph takes its
flattened parameter list as leading arguments. The rust runtime uploads
`<group>_weights.npz` to device buffers once at startup and passes them
by reference on every call (PjRtLoadedExecutable::execute_b), so the hot
path never re-copies weights. artifacts/manifest.json records, for every
graph: the HLO file, the weight group + ordered weight names, and the
input/output specs the rust engine validates against.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import dims, encoders, model, probe as probe_mod
from .dims import (
    AUDIO_D,
    AUDIO_T,
    AUD_SLOTS,
    C_FEAT,
    D_ENC,
    DRAFT,
    FULL,
    GRID,
    N_FRAMES,
    N_MODALITIES,
    N_PATCH,
    N_SPEC,
    PATCH_DIM,
    TEXT_SLOTS,
    VIS_SLOTS,
    VOCAB,
)

SEED = 42
# Pallas kernels in the model graphs (probe graphs always use them). The
# interpret-mode attention lowers to HLO while-loops; set to False to fall
# back to the fused jnp path if artifact execution time ever regresses.
PALLAS_IN_MODELS = True


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def s32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def flatten(params: dict):
    names = sorted(params.keys())
    return names, [params[n] for n in names]


class Builder:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.manifest = {
            "graphs": {},
            "weights": {},
            "constants": {
                "VOCAB": VOCAB,
                "PAD": dims.PAD,
                "BOS": dims.BOS,
                "EOS": dims.EOS,
                "SEP": dims.SEP,
                "ANS_BASE": dims.ANS_BASE,
                "GRID": GRID,
                "N_PATCH": N_PATCH,
                "PATCH_DIM": PATCH_DIM,
                "D_ENC": D_ENC,
                "C_FEAT": C_FEAT,
                "N_FRAMES": N_FRAMES,
                "FRAME_TOK": dims.FRAME_TOK,
                "AUDIO_T": AUDIO_T,
                "AUDIO_D": AUDIO_D,
                "VIS_SLOTS": VIS_SLOTS,
                "AUD_SLOTS": AUD_SLOTS,
                "TEXT_SLOTS": TEXT_SLOTS,
                "GEN_SLOTS": dims.GEN_SLOTS,
                "S_PRE": dims.S_PRE,
                "S_MAX": dims.S_MAX,
                "VIS_OFF": dims.VIS_OFF,
                "AUD_OFF": dims.AUD_OFF,
                "TEXT_OFF": dims.TEXT_OFF,
                "GEN_OFF": dims.GEN_OFF,
                "N_SPEC": N_SPEC,
                "LSH_K": dims.LSH_K,
                "N_MODALITIES": N_MODALITIES,
                "DH": dims.DH,
                "DRAFT_D": DRAFT.d,
                "DRAFT_LAYERS": DRAFT.n_layers,
                "DRAFT_HEADS": DRAFT.n_heads,
                "DRAFT_FFN": DRAFT.ffn,
                "DRAFT_PARAMS": int(DRAFT.n_params),
                "FULL_D": FULL.d,
                "FULL_LAYERS": FULL.n_layers,
                "FULL_HEADS": FULL.n_heads,
                "FULL_FFN": FULL.ffn,
                "FULL_PARAMS": int(FULL.n_params),
                "ENC_LAYERS": encoders.ENC_LAYERS,
                "ENC_HEADS": encoders.ENC_HEADS,
                "ENC_FFN": encoders.ENC_FFN,
            },
        }

    def add_weights(self, group, params):
        names, vals = flatten(params)
        path = os.path.join(self.out_dir, f"{group}_weights.npz")
        np.savez(path, **{n: np.asarray(v) for n, v in zip(names, vals)})
        self.manifest["weights"][group] = {
            "file": f"{group}_weights.npz",
            "names": names,
        }
        return names, vals

    def add_graph(self, name, core, weight_group, weight_vals, input_specs):
        """core(*weights, *inputs) -> tuple of outputs."""
        n_w = len(weight_vals)
        w_specs = [jax.ShapeDtypeStruct(v.shape, v.dtype) for v in weight_vals]
        # keep_unused: probe graphs touch only a subset of their weight
        # group; the rust engine always passes the whole group, so the
        # lowered signature must keep every arg.
        lowered = jax.jit(core, keep_unused=True).lower(*w_specs, *input_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        out_specs = [
            {"shape": list(s.shape), "dtype": s.dtype.name}
            for s in jax.tree_util.tree_leaves(lowered.out_info)
        ]
        self.manifest["graphs"][name] = {
            "file": fname,
            "weights": weight_group,
            "n_weight_args": n_w,
            "inputs": [
                {"shape": list(s.shape), "dtype": s.dtype.name}
                for s in input_specs
            ],
            "outputs": out_specs,
        }
        print(f"  {name}: {len(text)/1e6:.2f} MB hlo, {n_w} weight args")


def build(out_dir):
    os.makedirs(out_dir, exist_ok=True)
    b = Builder(out_dir)
    key = jax.random.PRNGKey(SEED)
    k_vis, k_aud, k_probe, k_draft, k_full = jax.random.split(key, 5)

    vis_p = encoders.init_vision(k_vis)
    aud_p = encoders.init_audio(k_aud)
    print("training probe heads on the synthetic distribution...")
    probe_p = probe_mod.train_probe(k_probe, vis_p, aud_p, verbose=True)
    draft_p = model.init_params(k_draft, DRAFT)
    full_p = model.init_params(k_full, FULL)

    vis_names, vis_vals = b.add_weights("vision", vis_p)
    aud_names, aud_vals = b.add_weights("audio", aud_p)
    probe_names, probe_vals = b.add_weights("probe", probe_p)
    draft_names, draft_vals = b.add_weights("draft", draft_p)
    full_names, full_vals = b.add_weights("full", full_p)

    def rebuild(names):
        def f(ws):
            return dict(zip(names, ws))

        return f

    # --- encoders ---------------------------------------------------------
    nv = len(vis_names)

    def g_vision(*args):
        p = dict(zip(vis_names, args[:nv]))
        return encoders.vision_encode(
            p, args[nv], use_pallas=PALLAS_IN_MODELS
        )

    b.add_graph(
        "vision_encoder", g_vision, "vision", vis_vals,
        [f32(N_PATCH, PATCH_DIM)],
    )

    na = len(aud_names)

    def g_audio(*args):
        p = dict(zip(aud_names, args[:na]))
        return encoders.audio_encode(p, args[na])

    b.add_graph(
        "audio_encoder", g_audio, "audio", aud_vals, [f32(AUDIO_T, AUDIO_D)]
    )

    # --- probes -----------------------------------------------------------
    np_ = len(probe_names)

    def g_spatial(*args):
        p = dict(zip(probe_names, args[:np_]))
        return (probe_mod.probe_spatial(p, args[np_]),)

    b.add_graph(
        "probe_spatial", g_spatial, "probe", probe_vals,
        [f32(GRID, GRID, C_FEAT)],
    )

    def g_temporal(*args):
        p = dict(zip(probe_names, args[:np_]))
        return (probe_mod.probe_temporal(p, args[np_]),)

    b.add_graph(
        "probe_temporal", g_temporal, "probe", probe_vals,
        [f32(N_FRAMES, D_ENC)],
    )

    def g_modal(*args):
        p = dict(zip(probe_names, args[:np_]))
        return (probe_mod.probe_modal(p, args[np_], args[np_ + 1], args[np_ + 2]),)

    b.add_graph(
        "probe_modal", g_modal, "probe", probe_vals,
        [s32(TEXT_SLOTS), s32(), f32(N_MODALITIES, D_ENC)],
    )

    def g_prune(*args):
        return probe_mod.prune_tokens(args[0], args[1], args[2])

    b.add_graph(
        "prune_tokens", g_prune, None, [],
        [f32(N_PATCH, D_ENC), f32(GRID, GRID), f32(1)],
    )

    # --- models -----------------------------------------------------------
    def model_graphs(tag, cfg, names, vals):
        nw = len(names)
        kv_spec = f32(cfg.n_layers, 2, cfg.n_heads, dims.S_MAX, dims.DH)

        def g_prefill(*args):
            p = dict(zip(names, args[:nw]))
            text, tlen, vis, vlen, aud, alen = args[nw : nw + 6]
            return model.prefill(
                p, cfg, text, tlen, vis, vlen, aud, alen,
                use_pallas=PALLAS_IN_MODELS,
            )

        b.add_graph(
            f"{tag}_prefill", g_prefill, tag, vals,
            [
                s32(TEXT_SLOTS), s32(),
                f32(VIS_SLOTS, D_ENC), s32(),
                f32(AUD_SLOTS, D_ENC), s32(),
            ],
        )

        def make_decode(n_tok):
            def g(*args):
                p = dict(zip(names, args[:nw]))
                kv, pos, toks, vlen, alen, tlen = args[nw : nw + 6]
                return model.block_decode(
                    p, cfg, kv, pos, toks, vlen, alen, tlen,
                    use_pallas=PALLAS_IN_MODELS,
                )

            return g

        b.add_graph(
            f"{tag}_decode", make_decode(1), tag, vals,
            [kv_spec, s32(), s32(1), s32(), s32(), s32()],
        )
        if tag == "full":
            b.add_graph(
                "full_verify", make_decode(N_SPEC), tag, vals,
                [kv_spec, s32(), s32(N_SPEC), s32(), s32(), s32()],
            )

    model_graphs("draft", DRAFT, draft_names, draft_vals)
    model_graphs("full", FULL, full_names, full_vals)

    golden = make_golden(draft_p, full_p, vis_p, probe_p)
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(golden, f)
    print("golden.json written")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(b.manifest, f, indent=1)
    print(f"manifest: {len(b.manifest['graphs'])} graphs -> {out_dir}")


def make_golden(draft_p, full_p, vis_p, probe_p):
    """Fixed-input expected outputs for the rust engine's numeric
    integration test (rust/tests/engine_golden.rs). Uses the same
    Pallas-bearing graphs that were lowered to HLO."""
    text = np.full((TEXT_SLOTS,), dims.PAD, np.int32)
    text[:4] = [dims.BOS, 72, 73, dims.SEP]
    tlen = jnp.int32(4)
    vis = jnp.asarray(
        np.linspace(-1, 1, VIS_SLOTS * D_ENC, dtype=np.float32).reshape(
            VIS_SLOTS, D_ENC
        )
    )
    vlen = jnp.int32(100)
    aud = jnp.zeros((AUD_SLOTS, D_ENC), jnp.float32)
    alen = jnp.int32(0)
    t = jnp.asarray(text)

    out = {}
    kv_d, logits_d = jax.jit(
        lambda: model.prefill(
            draft_p, DRAFT, t, tlen, vis, vlen, aud, alen,
            use_pallas=PALLAS_IN_MODELS,
        )
    )()
    out["draft_prefill_logits"] = np.asarray(logits_d).tolist()
    lg, _ = jax.jit(
        lambda: model.block_decode(
            draft_p, DRAFT, kv_d, jnp.int32(dims.GEN_OFF),
            jnp.asarray([42], jnp.int32), vlen, alen, tlen,
            use_pallas=PALLAS_IN_MODELS,
        )
    )()
    out["draft_decode_logits"] = np.asarray(lg[0]).tolist()

    kv_f, logits_f = jax.jit(
        lambda: model.prefill(
            full_p, FULL, t, tlen, vis, vlen, aud, alen,
            use_pallas=PALLAS_IN_MODELS,
        )
    )()
    out["full_prefill_logits"] = np.asarray(logits_f).tolist()
    vtoks = jnp.asarray([42, 7, 300, 264, 11, 99], jnp.int32)
    vlg, _ = jax.jit(
        lambda: model.block_decode(
            full_p, FULL, kv_f, jnp.int32(dims.GEN_OFF), vtoks, vlen, alen,
            tlen, use_pallas=PALLAS_IN_MODELS,
        )
    )()
    out["full_verify_row0"] = np.asarray(vlg[0]).tolist()
    out["full_verify_row5"] = np.asarray(vlg[5]).tolist()

    patches = jnp.asarray(
        np.sin(np.arange(N_PATCH * PATCH_DIM, dtype=np.float32) * 0.01).reshape(
            N_PATCH, PATCH_DIM
        )
    )
    tokens, tok32, feat, pooled = jax.jit(
        lambda: encoders.vision_encode(vis_p, patches, use_pallas=PALLAS_IN_MODELS)
    )()
    out["vision_pooled"] = np.asarray(pooled).tolist()
    imp = jax.jit(lambda: probe_mod.probe_spatial(probe_p, feat))()
    out["probe_spatial_map_row0"] = np.asarray(imp[0]).tolist()
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    build(args.out)


if __name__ == "__main__":
    main()
