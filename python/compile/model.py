"""L2: the multimodal transformer pair (edge draft / cloud full).

Substitution (DESIGN.md §3): stands in for Qwen2-VL-2B (edge) and
Qwen2.5-VL-7B (cloud). Both variants share the tokenizer, vocabulary,
head dim and sequence layout so speculative verification is seamless —
exactly the property the paper relies on ("the two models share the same
tokenizer and architectural design").

Fixed slot layout (dims.py): [0,192) visual | [192,224) audio |
[224,288) text | [288,352) generated. Padding inside segments is masked,
so a single AOT artifact serves every input length.

Two code paths, numerically interchangeable:
  use_pallas=True  — attention runs through the L1 flash-style kernel
                     (kernels/attention.py); this is what aot.py lowers.
  use_pallas=False — pure-jnp reference (kernels/ref.py) used by pytest to
                     validate the kernel-bearing graph end to end.
"""

import jax
import jax.numpy as jnp

from . import dims
from .dims import (
    AUD_OFF,
    DH,
    GEN_OFF,
    S_MAX,
    S_PRE,
    TEXT_OFF,
    VIS_OFF,
    VIS_SLOTS,
    AUD_SLOTS,
    TEXT_SLOTS,
    VOCAB,
)
from .kernels import ref
from .kernels.attention import NEG, attention

# ---------------------------------------------------------------------------
# Parameter init (deterministic; weights land in artifacts/<name>_weights.npz)
# ---------------------------------------------------------------------------


def _dense(key, din, dout, scale=None):
    scale = scale if scale is not None else 1.0 / jnp.sqrt(jnp.float32(din))
    return jax.random.normal(key, (din, dout), jnp.float32) * scale


PRIOR_SEED = 1234  # shared by both models: the common token-transition prior
PRIOR_ROW_SCALE = (1.2, 5.5)  # per-row temperature spread (skewed confident)
MODEL_LOGIT_SCALE = {"draft": 0.9, "full": 0.35}  # per-model deviation


def _shared_prior(cfg_name):
    """Token-transition prior shared by draft and full (plus small
    per-model perturbation). This is the substitution for trained-model
    agreement: greedy speculative decoding needs the draft's argmax to
    match the full model's most of the time (paper measures 70-85%
    acceptance on real Qwen pairs). Both models' logits are
    prior[last_token] + scale * transformer(x); the shared prior row
    dominates, the transformer term injects input-dependent deviation —
    larger for the draft, so acceptance is high but not trivial, and the
    entropy of confident vs unconfident rows varies naturally."""
    kp = jax.random.PRNGKey(PRIOR_SEED)
    k1, k2, k3 = jax.random.split(kp, 3)
    base = jax.random.normal(k1, (VOCAB, VOCAB), jnp.float32)
    lo, hi = PRIOR_ROW_SCALE
    # Skew toward confident rows (u^0.35): a trained LM is confident on
    # most steps and uncertain on a minority — that minority is what the
    # entropy gate (Eq. 10) exists to catch.
    u = jax.random.uniform(k2, (VOCAB, 1)) ** 0.35
    row_scale = lo + (hi - lo) * u
    prior = base * row_scale
    # Discourage EOS so generations run to length; keep PAD unreachable.
    prior = prior.at[:, dims.EOS].add(-3.0)
    prior = prior.at[:, dims.PAD].add(-8.0)
    # Per-model perturbation (input-independent part of the deviation).
    km = jax.random.fold_in(k3, 0 if cfg_name == "draft" else 1)
    eps = {"draft": 0.32, "full": 0.1}[cfg_name]
    return prior + eps * jax.random.normal(km, (VOCAB, VOCAB), jnp.float32)


def init_params(key, cfg: dims.ModelCfg) -> dict:
    """Flat name->array dict. Sorted names define the manifest arg order."""
    p = {}
    keys = iter(jax.random.split(key, 16 + 12 * cfg.n_layers))
    p["prior"] = _shared_prior(cfg.name)
    p["embed"] = _dense(next(keys), VOCAB, cfg.d, scale=0.02)
    p["pos"] = _dense(next(keys), S_MAX, cfg.d, scale=0.02)
    p["vis_proj"] = _dense(next(keys), dims.D_ENC, cfg.d)
    p["aud_proj"] = _dense(next(keys), dims.D_ENC, cfg.d)
    for l in range(cfg.n_layers):
        pre = f"layers_{l:02d}_"
        p[pre + "ln1_s"] = jnp.ones((cfg.d,), jnp.float32)
        p[pre + "ln1_b"] = jnp.zeros((cfg.d,), jnp.float32)
        p[pre + "wq"] = _dense(next(keys), cfg.d, cfg.d)
        p[pre + "wk"] = _dense(next(keys), cfg.d, cfg.d)
        p[pre + "wv"] = _dense(next(keys), cfg.d, cfg.d)
        p[pre + "wo"] = _dense(next(keys), cfg.d, cfg.d)
        p[pre + "ln2_s"] = jnp.ones((cfg.d,), jnp.float32)
        p[pre + "ln2_b"] = jnp.zeros((cfg.d,), jnp.float32)
        p[pre + "w1"] = _dense(next(keys), cfg.d, cfg.ffn)
        p[pre + "b1"] = jnp.zeros((cfg.ffn,), jnp.float32)
        p[pre + "w2"] = _dense(next(keys), cfg.ffn, cfg.d)
        p[pre + "b2"] = jnp.zeros((cfg.d,), jnp.float32)
    p["lnf_s"] = jnp.ones((cfg.d,), jnp.float32)
    p["lnf_b"] = jnp.zeros((cfg.d,), jnp.float32)
    return p


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def _ln(x, s, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * s + b


def _heads(x, n_heads):
    # [S, D] -> [H, S, Dh]
    s = x.shape[0]
    return x.reshape(s, n_heads, DH).transpose(1, 0, 2)


def _unheads(x):
    # [H, S, Dh] -> [S, D]
    h, s, dh = x.shape
    return x.transpose(1, 0, 2).reshape(s, h * dh)


def _attn(q, k, v, mask, use_pallas):
    if use_pallas:
        sq, sk = q.shape[1], k.shape[1]
        bq = 48 if sq % 48 == 0 and sq >= 48 else sq
        # Perf pass (EXPERIMENTS.md §Perf L1): largest K/V block that
        # divides Sk — fewer interpret-loop iterations per q block and a
        # better HBM->VMEM streaming ratio on real TPU (VMEM per block at
        # paper scale stays ~100 KiB, far under budget; DESIGN.md §8).
        bk = next(b for b in (96, 88, 64, 48, 32, 16, 8) if sk % b == 0)
        return attention(q, k, v, mask, bq=bq, bk=bk)
    return ref.attention_ref(q, k, v, mask)


def _valid_slots(vlen, alen, tlen):
    """Boolean [S_MAX] validity of prefill slots given segment lengths."""
    s = jnp.arange(S_MAX)
    vis = (s >= VIS_OFF) & (s < VIS_OFF + jnp.minimum(vlen, VIS_SLOTS))
    aud = (s >= AUD_OFF) & (s < AUD_OFF + jnp.minimum(alen, AUD_SLOTS))
    txt = (s >= TEXT_OFF) & (s < TEXT_OFF + jnp.minimum(tlen, TEXT_SLOTS))
    return vis | aud | txt


def _block(params, l, x, attn_out):
    pre = f"layers_{l:02d}_"
    x = x + attn_out @ params[pre + "wo"]
    xn = _ln(x, params[pre + "ln2_s"], params[pre + "ln2_b"])
    h = jax.nn.relu(xn @ params[pre + "w1"] + params[pre + "b1"])
    return x + h @ params[pre + "w2"] + params[pre + "b2"]


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def prefill(params, cfg, text, tlen, vis, vlen, aud, alen, *, use_pallas=True):
    """Process the assembled multimodal prompt; build the KV cache.

    text: [TEXT_SLOTS] i32; vis: [VIS_SLOTS, D_ENC]; aud: [AUD_SLOTS, D_ENC];
    *len: i32 scalars (actual lengths; the rest is padding).
    Returns (kv [L, 2, H, S_MAX, DH], logits [VOCAB] at the last text pos).
    """
    n_heads = cfg.n_heads
    x = jnp.concatenate(
        [
            vis @ params["vis_proj"],
            aud @ params["aud_proj"],
            params["embed"][text],
        ],
        axis=0,
    )  # [S_PRE, D]
    x = x + params["pos"][:S_PRE]

    valid = _valid_slots(vlen, alen, tlen)[:S_PRE]
    i = jnp.arange(S_PRE)
    mask = jnp.where(valid[None, :] & (i[None, :] <= i[:, None]), 0.0, NEG)

    kv = jnp.zeros((cfg.n_layers, 2, n_heads, S_MAX, DH), jnp.float32)
    for l in range(cfg.n_layers):
        pre = f"layers_{l:02d}_"
        xn = _ln(x, params[pre + "ln1_s"], params[pre + "ln1_b"])
        q = _heads(xn @ params[pre + "wq"], n_heads)
        k = _heads(xn @ params[pre + "wk"], n_heads)
        v = _heads(xn @ params[pre + "wv"], n_heads)
        kv = kv.at[l, 0, :, :S_PRE].set(k)
        kv = kv.at[l, 1, :, :S_PRE].set(v)
        o = _unheads(_attn(q, k, v, mask, use_pallas))
        x = _block(params, l, x, o)
    xf = _ln(x, params["lnf_s"], params["lnf_b"])
    last = TEXT_OFF + jnp.maximum(tlen, 1) - 1
    scale = MODEL_LOGIT_SCALE[cfg.name] / jnp.sqrt(jnp.float32(cfg.d))
    logits = params["prior"][text[jnp.maximum(tlen, 1) - 1]] + scale * (
        xf[last] @ params["embed"].T
    )  # [VOCAB]
    return kv, logits


# ---------------------------------------------------------------------------
# Block decode (N=1 -> decode step; N=N_SPEC -> speculative verify)
# ---------------------------------------------------------------------------


def block_decode(
    params, cfg, kv, start_pos, tokens, vlen, alen, tlen, *, use_pallas=True
):
    """Decode `tokens` at absolute slots [start_pos, start_pos+N).

    kv: [L, 2, H, S_MAX, DH] (the block's slots are overwritten);
    start_pos: i32 scalar (>= GEN_OFF); tokens: [N] i32 (N static).
    logits[r] predicts the token *after* tokens[r].
    Returns (logits [N, VOCAB], kv').
    """
    n = tokens.shape[0]
    n_heads = cfg.n_heads

    rows = start_pos + jnp.arange(n)
    x = params["embed"][tokens] + params["pos"][rows]  # [N, D]

    # Mask: prefill slots valid per lengths; generated slots valid if
    # GEN_OFF <= j < start_pos; block slots causal within the block.
    j = jnp.arange(S_MAX)
    base = _valid_slots(vlen, alen, tlen) | ((j >= GEN_OFF) & (j < start_pos))
    r = jnp.arange(n)
    in_block = (j[None, :] >= start_pos) & (j[None, :] <= start_pos + r[:, None])
    mask = jnp.where(base[None, :] | in_block, 0.0, NEG)  # [N, S_MAX]

    for l in range(cfg.n_layers):
        pre = f"layers_{l:02d}_"
        xn = _ln(x, params[pre + "ln1_s"], params[pre + "ln1_b"])
        q = _heads(xn @ params[pre + "wq"], n_heads)  # [H, N, Dh]
        k_new = _heads(xn @ params[pre + "wk"], n_heads)
        v_new = _heads(xn @ params[pre + "wv"], n_heads)
        kv = jax.lax.dynamic_update_slice(
            kv, k_new[None, None], (l, 0, 0, start_pos, 0)
        )
        kv = jax.lax.dynamic_update_slice(
            kv, v_new[None, None], (l, 1, 0, start_pos, 0)
        )
        o = _unheads(_attn(q, kv[l, 0], kv[l, 1], mask, use_pallas))
        x = _block(params, l, x, o)
    xf = _ln(x, params["lnf_s"], params["lnf_b"])
    scale = MODEL_LOGIT_SCALE[cfg.name] / jnp.sqrt(jnp.float32(cfg.d))
    logits = params["prior"][tokens] + scale * (xf @ params["embed"].T)
    return logits, kv  # [N, VOCAB], kv'
