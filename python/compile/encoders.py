"""L2: modality encoders shared by both model variants (Eq. 1-2).

The vision encoder is the f_v(.) of Eq. 1: a 2-layer ViT over the 16x16
patch grid. It additionally exposes the *early-layer* feature map the
paper's spatial probe attaches to (§4.1.1: "early layers in vision
encoders capture spatial structures with minimal computational overhead")
and a pooled summary vector used by the temporal-LSH and modal probes.

The audio encoder is a light MLP over mel-style frames — audio carries no
spatial/temporal probe dimensions in MSAO, only modal relevance.
"""

import jax
import jax.numpy as jnp

from . import dims
from .dims import C_FEAT, D_ENC, DH, GRID, N_PATCH, PATCH_DIM
from .kernels import ref
from .kernels.attention import attention

ENC_LAYERS = 2
ENC_HEADS = 4
ENC_FFN = 256


def _dense(key, din, dout, scale=None):
    scale = scale if scale is not None else 1.0 / jnp.sqrt(jnp.float32(din))
    return jax.random.normal(key, (din, dout), jnp.float32) * scale


def init_vision(key) -> dict:
    p = {}
    keys = iter(jax.random.split(key, 4 + 8 * ENC_LAYERS))
    p["patch_proj"] = _dense(next(keys), PATCH_DIM, D_ENC)
    p["pos"] = _dense(next(keys), N_PATCH, D_ENC, scale=0.02)
    p["feat_proj"] = _dense(next(keys), D_ENC, C_FEAT)
    for l in range(ENC_LAYERS):
        pre = f"enc_{l:02d}_"
        p[pre + "ln1_s"] = jnp.ones((D_ENC,), jnp.float32)
        p[pre + "ln1_b"] = jnp.zeros((D_ENC,), jnp.float32)
        p[pre + "wq"] = _dense(next(keys), D_ENC, D_ENC)
        p[pre + "wk"] = _dense(next(keys), D_ENC, D_ENC)
        p[pre + "wv"] = _dense(next(keys), D_ENC, D_ENC)
        p[pre + "wo"] = _dense(next(keys), D_ENC, D_ENC)
        p[pre + "ln2_s"] = jnp.ones((D_ENC,), jnp.float32)
        p[pre + "ln2_b"] = jnp.zeros((D_ENC,), jnp.float32)
        p[pre + "w1"] = _dense(next(keys), D_ENC, ENC_FFN)
        p[pre + "b1"] = jnp.zeros((ENC_FFN,), jnp.float32)
        p[pre + "w2"] = _dense(next(keys), ENC_FFN, D_ENC)
        p[pre + "b2"] = jnp.zeros((D_ENC,), jnp.float32)
    return p


def init_audio(key) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "a_w1": _dense(k1, dims.AUDIO_D, D_ENC),
        "a_b1": jnp.zeros((D_ENC,), jnp.float32),
        "a_w2": _dense(k2, D_ENC, D_ENC),
        "a_b2": jnp.zeros((D_ENC,), jnp.float32),
    }


def _ln(x, s, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * s + b


def vision_encode(p, patches, *, use_pallas=True):
    """patches: [N_PATCH, PATCH_DIM] ->
    (tokens [N_PATCH, D_ENC]      full-resolution visual tokens,
     tokens32 [FRAME_TOK, D_ENC]  pooled tokens for video-frame use,
     feat [GRID, GRID, C_FEAT]    early-layer probe feature map,
     pooled [D_ENC]               global summary for LSH/modal probes).
    """
    x = patches @ p["patch_proj"] + p["pos"]
    zero_mask = jnp.zeros((N_PATCH, N_PATCH), jnp.float32)  # bidirectional
    feat = None
    for l in range(ENC_LAYERS):
        pre = f"enc_{l:02d}_"
        xn = _ln(x, p[pre + "ln1_s"], p[pre + "ln1_b"])
        q = xn @ p[pre + "wq"]
        k = xn @ p[pre + "wk"]
        v = xn @ p[pre + "wv"]
        to_h = lambda t: t.reshape(N_PATCH, ENC_HEADS, DH).transpose(1, 0, 2)
        if use_pallas:
            o = attention(to_h(q), to_h(k), to_h(v), zero_mask, bq=64, bk=64)
        else:
            o = ref.attention_ref(to_h(q), to_h(k), to_h(v), zero_mask)
        o = o.transpose(1, 0, 2).reshape(N_PATCH, D_ENC)
        x = x + o @ p[pre + "wo"]
        xn = _ln(x, p[pre + "ln2_s"], p[pre + "ln2_b"])
        x = x + jax.nn.relu(xn @ p[pre + "w1"] + p[pre + "b1"]) @ p[pre + "w2"]
        if l == 0:
            # Early-layer feature map for the spatial probe (Eq. 3).
            feat = (x @ p["feat_proj"]).reshape(GRID, GRID, C_FEAT)
    tokens = x
    tokens32 = jnp.mean(
        x.reshape(dims.FRAME_TOK, N_PATCH // dims.FRAME_TOK, D_ENC), axis=1
    )
    pooled = jnp.mean(x, axis=0)
    return tokens, tokens32, feat, pooled


def audio_encode(p, audio):
    """audio: [AUDIO_T, AUDIO_D] -> (tokens [AUDIO_T, D_ENC], pooled [D_ENC])."""
    h = jax.nn.relu(audio @ p["a_w1"] + p["a_b1"])
    tokens = h @ p["a_w2"] + p["a_b2"]
    return tokens, jnp.mean(tokens, axis=0)
