"""L2: the lightweight modality-aware probing network (paper §4.1).

Wires the three L1 probe kernels into AOT-able graphs:
  - probe_spatial : feature map -> importance map (Eq. 3; kernel
                    spatial_probe) — ratio rho_spatial (Eq. 4) and the
                    tau_s threshold live on the rust side where the
                    config is known.
  - probe_temporal: per-frame pooled features -> gamma_t (Eq. 5; kernel
                    lsh_gamma).
  - probe_modal   : prompt tokens + pooled modality reps -> alpha_m
                    (Eq. 6; kernel modal_scores). Softmax into beta_m is
                    masked on the rust side for absent modalities.
  - prune_tokens  : visual tokens + importance -> compacted tokens
                    (kernel token_prune), feeding the prefill vis slots.

MAS itself (Eq. 7) is pure scalar arithmetic over these outputs and is
computed in rust/src/sparsity.
"""

import jax
import jax.numpy as jnp

from . import dims
from .dims import D_ENC, D_PROBE, GRID, LSH_K, N_MODALITIES, TEXT_SLOTS, VOCAB
from .kernels import ref
from .kernels.lsh_probe import lsh_gamma
from .kernels.modal_probe import modal_scores
from .kernels.spatial_probe import spatial_probe
from .kernels.token_prune import token_prune

MLP_H = 64


def _dense(key, din, dout, scale=None):
    scale = scale if scale is not None else 1.0 / jnp.sqrt(jnp.float32(din))
    return jax.random.normal(key, (din, dout), jnp.float32) * scale


def train_probe(key, vision_params, audio_params, *, n_train=300, steps=300,
                lr=0.05, seed=7, verbose=False) -> dict:
    """Train the probe heads on the synthetic distribution (synth.py).

    - Spatial head (sp_w, sp_b): logistic regression from the encoder's
      early-layer feature map to the per-patch salience label.
    - Modal head (pe, zproj, w1, b1, w2, b2): cross-entropy on "which
      modality does this prompt reference", from (prompt tokens, pooled
      modality features).
    The LSH projection needs no training (hash similarity is intrinsic).
    Mirrors the paper's offline-trained lightweight probing network.
    """
    import numpy as np

    from . import encoders, synth

    p = init_probe(key)
    rng = np.random.default_rng(seed)

    # --- spatial head -------------------------------------------------
    enc = jax.jit(lambda x: encoders.vision_encode(vision_params, x, use_pallas=False))
    feats, labels = [], []
    for _ in range(n_train):
        patches, mask = synth.make_image(rng)
        _, _, feat, _ = enc(jnp.asarray(patches))
        feats.append(np.asarray(feat).reshape(-1, dims.C_FEAT))
        labels.append(mask.astype(np.float32))
    x = jnp.asarray(np.concatenate(feats))          # [N*256, C]
    y = jnp.asarray(np.concatenate(labels))         # [N*256]

    def sp_loss(params):
        w, b = params
        logit = x @ w + b[0]
        return jnp.mean(
            jnp.maximum(logit, 0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit)))
        )

    sp = (p["sp_w"], p["sp_b"])
    g = jax.jit(jax.value_and_grad(sp_loss))
    for i in range(steps):
        loss, grads = g(sp)
        sp = tuple(a - lr * 4.0 * da for a, da in zip(sp, grads))
    p["sp_w"], p["sp_b"] = sp
    if verbose:
        print(f"  spatial probe loss {float(loss):.4f}")

    # --- modal head -----------------------------------------------------
    aud_enc = jax.jit(lambda a: encoders.audio_encode(audio_params, a))
    xs_text, xs_pooled, ys = [], [], []
    for _ in range(n_train):
        m = int(rng.integers(0, dims.N_MODALITIES))
        text, tlen = synth.make_question(rng, m)
        pooled = np.zeros((dims.N_MODALITIES, dims.D_ENC), np.float32)
        patches, _ = synth.make_image(rng)
        _, _, _, pv = enc(jnp.asarray(patches))
        pooled[1] = np.asarray(pv)
        pooled[2] = pooled[1] + 0.1 * rng.standard_normal(dims.D_ENC)
        _, pa = aud_enc(jnp.asarray(synth.make_audio(rng)))
        pooled[3] = np.asarray(pa)
        pooled[0] = 0.0
        xs_text.append(text)
        xs_pooled.append(pooled)
        ys.append(m)
    xt = jnp.asarray(np.stack(xs_text))
    xp = jnp.asarray(np.stack(xs_pooled))
    yy = jnp.asarray(np.asarray(ys, np.int32))

    def modal_loss(params):
        pe, zproj, te, w1, b1, w2, b2 = params
        emb = pe[xt]                                   # [B, T, Dp]
        m = (xt != 256).astype(jnp.float32)            # PAD mask
        prompt = (emb * m[..., None]).sum(1) / jnp.maximum(m.sum(1, keepdims=True), 1.0)
        prompt = prompt / (jnp.linalg.norm(prompt, axis=-1, keepdims=True) + 1e-6)
        z = xp @ zproj + te                            # [B, M, Dp]
        z = z / (jnp.linalg.norm(z, axis=-1, keepdims=True) + 1e-6)
        cat = jnp.concatenate(
            [jnp.broadcast_to(prompt[:, None, :], z.shape), z], -1
        )
        h = jax.nn.relu(cat @ w1 + b1)
        logits = h @ w2 + b2[0]                        # [B, M]
        lp = jax.nn.log_softmax(logits, -1)
        return -jnp.mean(jnp.take_along_axis(lp, yy[:, None], 1))

    mp = (p["pe"], p["zproj"], p["type_emb"], p["w1"], p["b1"], p["w2"], p["b2"])
    g2 = jax.jit(jax.value_and_grad(modal_loss))
    for i in range(4 * steps):
        loss2, grads = g2(mp)
        # Clip by global norm for stability at this lr.
        gn = jnp.sqrt(sum(jnp.sum(g * g) for g in grads))
        clip = jnp.minimum(1.0, 1.0 / (gn + 1e-9))
        mp = tuple(a - 2.0 * lr * clip * da for a, da in zip(mp, grads))
    p["pe"], p["zproj"], p["type_emb"], p["w1"], p["b1"], p["w2"], p["b2"] = mp
    if verbose:
        print(f"  modal probe loss {float(loss2):.4f}")
    return p


def init_probe(key) -> dict:
    keys = iter(jax.random.split(key, 8))
    return {
        "sp_w": jax.random.normal(next(keys), (dims.C_FEAT,), jnp.float32)
        * (1.0 / jnp.sqrt(jnp.float32(dims.C_FEAT))),
        "sp_b": jnp.zeros((1,), jnp.float32),
        "lsh_proj": jax.random.normal(
            next(keys), (D_ENC, LSH_K), jnp.float32
        ),
        "pe": _dense(next(keys), VOCAB, D_PROBE, scale=0.05),
        "zproj": _dense(next(keys), D_ENC, D_PROBE),
        "w1": _dense(next(keys), 2 * D_PROBE, MLP_H),
        "b1": jnp.zeros((MLP_H,), jnp.float32),
        "w2": jax.random.normal(next(keys), (MLP_H,), jnp.float32)
        * (1.0 / jnp.sqrt(jnp.float32(MLP_H))),
        "b2": jnp.zeros((1,), jnp.float32),
        # Modality type embedding added to z_m (segment-embedding style):
        # real encoders produce modality-distinct features; our synthetic
        # pooled vectors for image/video are near-identical, so the type
        # tag restores the separability Eq. 6 assumes.
        "type_emb": 0.1
        * jax.random.normal(next(keys), (N_MODALITIES, D_PROBE), jnp.float32),
    }


def probe_spatial(p, feat, *, use_pallas=True):
    """feat: [GRID, GRID, C_FEAT] -> importance map [GRID, GRID]."""
    if use_pallas:
        return spatial_probe(feat, p["sp_w"], p["sp_b"])
    return ref.spatial_probe_ref(feat, p["sp_w"], p["sp_b"][0])


def probe_temporal(p, frames, *, use_pallas=True):
    """frames: [N_FRAMES, D_ENC] pooled -> gamma [N_FRAMES]."""
    if use_pallas:
        return lsh_gamma(frames, p["lsh_proj"])
    return ref.lsh_gamma_ref(frames, p["lsh_proj"])


def probe_modal(p, text, tlen, pooled, *, use_pallas=True):
    """text: [TEXT_SLOTS] i32 prompt tokens; tlen: i32; pooled:
    [N_MODALITIES, D_ENC] per-modality summary vectors.
    Returns alpha [N_MODALITIES] raw relevance scores."""
    emb = p["pe"][text]  # [TEXT_SLOTS, D_PROBE]
    m = (jnp.arange(TEXT_SLOTS) < tlen).astype(jnp.float32)
    prompt = (emb * m[:, None]).sum(0) / jnp.maximum(m.sum(), 1.0)
    # Unit-normalize both branches (cosine-style relevance): without this
    # the pooled-feature magnitude swamps the prompt signal and the MLP
    # memorizes content noise instead of learning the keyword rule.
    prompt = prompt / (jnp.linalg.norm(prompt) + 1e-6)
    z = pooled @ p["zproj"] + p["type_emb"]  # [M, D_PROBE]
    z = z / (jnp.linalg.norm(z, axis=-1, keepdims=True) + 1e-6)
    if use_pallas:
        return modal_scores(prompt, z, p["w1"], p["b1"], p["w2"], p["b2"])
    return ref.modal_scores_ref(
        prompt, z, p["w1"], p["b1"], p["w2"], p["b2"][0]
    )


def prune_tokens(tokens, imp_map, tau, *, use_pallas=True):
    """tokens: [N_PATCH, D_ENC]; imp_map: [GRID, GRID]; tau: [1] f32.
    Returns (pruned [VIS_SLOTS, D_ENC], idx [VIS_SLOTS] i32, count [1])."""
    imp = imp_map.reshape(-1)
    if use_pallas:
        return token_prune(tokens, imp, tau, dims.VIS_SLOTS)
    out, idx, count = ref.token_prune_ref(tokens, imp, tau[0], dims.VIS_SLOTS)
    return out, idx, count[None]
