"""Pallas kernel: order-preserving visual-token compaction (Eq. 4 pruning).

Tokens whose spatial importance falls below tau_s are "non-critical
background" (paper §4.1.1) and are dropped before the sequence is
assembled. The kernel performs an in-VMEM stream compaction: a single
grid cell walks the N source rows with a fori_loop, keeping a running
write cursor and storing selected rows at their rank. On real TPU this
is a sequential scatter in VMEM (N=256 rows — trivially latency-bound);
the win is that only `keep` rows ever travel back to HBM.

Outputs match ref.token_prune_ref exactly: (pruned [keep, D] zero-padded,
idx [keep] source index or -1, count [1]).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(tok_ref, imp_ref, tau_ref, o_ref, idx_ref, cnt_ref, *, keep: int):
    n, _d = tok_ref.shape
    tau = tau_ref[0]
    o_ref[...] = jnp.zeros_like(o_ref)
    idx_ref[...] = jnp.full_like(idx_ref, -1)

    def body(i, cursor):
        sel = (imp_ref[i] >= tau) & (cursor < keep)

        def write(c):
            row = pl.load(tok_ref, (pl.dslice(i, 1), slice(None)))
            pl.store(o_ref, (pl.dslice(c, 1), slice(None)), row)
            pl.store(idx_ref, (pl.dslice(c, 1),), jnp.full((1,), i, jnp.int32))
            return c + 1

        return jax.lax.cond(sel, write, lambda c: c, cursor)

    cursor = jax.lax.fori_loop(0, n, body, jnp.int32(0))
    cnt_ref[0] = cursor


def token_prune(tokens, imp, tau, keep: int):
    """tokens: [N, D]; imp: [N]; tau: [1] f32; keep: static capacity.

    Returns (pruned [keep, D], idx [keep] i32, count [1] i32)."""
    n, d = tokens.shape
    kern = functools.partial(_kernel, keep=keep)
    return pl.pallas_call(
        kern,
        out_shape=[
            jax.ShapeDtypeStruct((keep, d), jnp.float32),
            jax.ShapeDtypeStruct((keep,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=True,
    )(tokens, imp, tau)
