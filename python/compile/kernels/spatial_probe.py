"""Pallas kernel: spatial importance map (Eq. 3).

TPU adaptation (DESIGN.md §6): the paper's CUDA conv1x1 head becomes a
row-tiled channel contraction — each grid step loads one row of the patch
feature map into VMEM and contracts the channel dim against the probe
weight vector, fusing the sigmoid. BlockSpec expresses the HBM->VMEM
schedule the paper did with thread blocks.

interpret=True everywhere: the CPU PJRT client cannot run Mosaic
custom-calls; correctness is validated against ref.spatial_probe_ref.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(feat_ref, w_ref, b_ref, o_ref):
    # feat_ref: [1, G, C] one row of the patch grid in VMEM
    f = feat_ref[0]                      # [G, C]
    w = w_ref[:]                         # [C]
    b = b_ref[0]
    o_ref[0, :] = jax.nn.sigmoid(f @ w + b)


def spatial_probe(feat, w, b):
    """feat: [G, G, C]; w: [C]; b: [1]. Returns importance map [G, G]."""
    g, g2, c = feat.shape
    assert g == g2
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((g, g), jnp.float32),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, g, c), lambda i: (i, 0, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, g), lambda i: (i, 0)),
        interpret=True,
    )(feat, w, b)
