"""Pallas kernel: cross-modal relevance scores alpha_m (Eq. 6).

Fuses the MLP([p; z_m]) over all M modalities in one VMEM-resident grid
cell: the prompt embedding is broadcast against the M modality reps, the
two matmuls hit the MXU, and the relu sits between them in-register.
Softmax normalisation into beta_m happens on the rust side where absent
modalities are masked (Eq. 6 footnote).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(p_ref, z_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    p = p_ref[...]                        # [Dp]
    z = z_ref[...]                        # [M, Dz]
    m = z.shape[0]
    x = jnp.concatenate(
        [jnp.broadcast_to(p, (m, p.shape[0])), z], axis=-1
    )                                     # [M, Dp+Dz]
    h = jax.nn.relu(x @ w1_ref[...] + b1_ref[...])
    o_ref[...] = h @ w2_ref[...] + b2_ref[0]


def modal_scores(p, z, w1, b1, w2, b2):
    """p: [Dp]; z: [M, Dz]; MLP weights as in ref.modal_scores_ref.

    Returns alpha: [M] raw relevance scores.
    """
    m = z.shape[0]
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,
    )(p, z, w1, b1, w2, b2)
