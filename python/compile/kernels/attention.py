"""Pallas kernel: the decode/prefill attention hot-spot.

TPU adaptation (DESIGN.md §6): flash-style blockwise softmax accumulation
sized to VMEM instead of the CUDA shared-memory tiling the paper implies.
The grid iterates (head, q-block); each step streams K/V blocks through
VMEM keeping a running max / running denominator so the full [Sq, Sk]
score matrix never materialises. Block sizes Bq=Bk=64 keep per-step VMEM
at Bq*Dh + 2*Bk*Dh + Bq*Bk floats (~24 KiB at Dh=32 f32), far under the
16 MiB VMEM budget — see DESIGN.md §8 for the roofline estimate.

Masking is additive ([Sq, Sk], 0 or NEG) and carries both causality and
slot-validity, so one kernel serves prefill, single-token decode and
N-token speculative verify.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e9


def _kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, bk: int, sk: int):
    # q_ref: [1, Bq, Dh] (one head, one q block); k/v_ref: [1, Sk, Dh];
    # mask_ref: [Bq, Sk]; o_ref: [1, Bq, Dh]
    q = q_ref[0]                                     # [Bq, Dh]
    bq, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))

    m_i = jnp.full((bq,), NEG, jnp.float32)          # running max
    l_i = jnp.zeros((bq,), jnp.float32)              # running denominator
    acc = jnp.zeros((bq, dh), jnp.float32)           # running numerator

    def body(j, carry):
        m_i, l_i, acc = carry
        k_blk = pl.load(k_ref, (0, pl.dslice(j * bk, bk), slice(None)))
        v_blk = pl.load(v_ref, (0, pl.dslice(j * bk, bk), slice(None)))
        msk = pl.load(mask_ref, (slice(None), pl.dslice(j * bk, bk)))
        s = q @ k_blk.T * scale + msk                # [Bq, Bk]
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_i - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_i * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ v_blk
        return m_new, l_new, acc_new

    m_i, l_i, acc = jax.lax.fori_loop(0, sk // bk, body, (m_i, l_i, acc))
    # NEG is finite, so even fully-masked (padding) rows have l_i > 0 and
    # degrade to a uniform average, matching ref.attention_ref; the model
    # never reads those rows. Guard anyway for true -inf masks.
    safe = jnp.where(l_i == 0.0, 1.0, l_i)
    o_ref[0] = acc / safe[:, None]


def attention(q, k, v, mask, *, bq: int = 64, bk: int = 64):
    """Flash-style attention. q: [H, Sq, Dh]; k/v: [H, Sk, Dh];
    mask: [Sq, Sk] additive. Sq, Sk must be multiples of bq, bk.
    Returns [H, Sq, Dh]."""
    h, sq, dh = q.shape
    sk = k.shape[1]
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    kern = functools.partial(_kernel, bk=bk, sk=sk)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((h, sq, dh), jnp.float32),
        grid=(h, sq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda hi, qi: (hi, qi, 0)),
            pl.BlockSpec((1, sk, dh), lambda hi, qi: (hi, 0, 0)),
            pl.BlockSpec((1, sk, dh), lambda hi, qi: (hi, 0, 0)),
            pl.BlockSpec((bq, sk), lambda hi, qi: (qi, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda hi, qi: (hi, qi, 0)),
        interpret=True,
    )(q, k, v, mask)
