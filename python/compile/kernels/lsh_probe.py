"""Pallas kernel: temporal redundancy via sign-LSH (Eq. 5).

TPU adaptation (DESIGN.md §6): the paper's per-frame hash (a CUDA warp
ballot over K hash functions) becomes one projected matmul
(frames[T,D] @ proj[D,K]) on the MXU with the sign comparison and the
adjacent-frame agreement count fused in-kernel as lane reductions — no
warp primitives needed. T and K are tiny, so a single VMEM-resident grid
cell holds everything.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(frames_ref, proj_ref, o_ref):
    frames = frames_ref[...]             # [T, D]
    proj = proj_ref[...]                 # [D, K]
    k = proj.shape[1]
    signs = (frames @ proj) >= 0.0       # [T, K] hash bits h_k(f_t)
    agree = jnp.sum(
        (signs[1:] == signs[:-1]).astype(jnp.float32), axis=-1
    ) / jnp.float32(k)                   # sim_t, t >= 1
    sim = jnp.concatenate([jnp.zeros((1,), jnp.float32), agree])
    o_ref[...] = 1.0 - sim               # gamma_t; gamma_0 = 1 (keep)


def lsh_gamma(frames, proj):
    """frames: [T, D] pooled per-frame features; proj: [D, K] hash planes.

    Returns gamma: [T], the temporal redundancy score per frame.
    """
    t, _d = frames.shape
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((t,), jnp.float32),
        interpret=True,
    )(frames, proj)
