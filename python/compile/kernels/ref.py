"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: each kernel in this package must
match its `*_ref` function to float tolerance under pytest/hypothesis
(python/tests/test_kernels.py). They are also used directly by model.py
when `use_pallas=False`, so the full model has a kernel-free reference
path for end-to-end numeric checks.
"""

import jax
import jax.numpy as jnp


def spatial_probe_ref(feat, w, b):
    """Spatial importance map (Eq. 3): sigmoid(conv1x1(avgpool(F))).

    feat: [G, G, C] early-layer feature map (already pooled over patch
    interior by the encoder); w: [C]; b: scalar.
    Returns [G, G] importance in (0, 1).
    """
    return jax.nn.sigmoid(jnp.einsum("ijc,c->ij", feat, w) + b)


def lsh_gamma_ref(frames, proj):
    """Temporal redundancy via sign-LSH (Eq. 5): gamma_t = 1 - sim_t.

    frames: [T, D] pooled per-frame features; proj: [D, K] random
    projections (the K hash functions). sim_t = fraction of hash bits
    agreeing between frames t and t-1; frame 0 has no predecessor so
    gamma_0 = 1 (always novel / must keep).
    Returns gamma: [T] in [0, 1].
    """
    signs = (frames @ proj) >= 0.0  # [T, K]
    agree = jnp.mean((signs[1:] == signs[:-1]).astype(jnp.float32), axis=-1)
    sim = jnp.concatenate([jnp.zeros((1,), jnp.float32), agree])
    return 1.0 - sim


def modal_scores_ref(p, z, w1, b1, w2, b2):
    """Cross-modal relevance scores alpha_m (Eq. 6): MLP([p; z_m]).

    p: [Dp] prompt embedding; z: [M, Dz] compressed modality reps;
    w1: [Dp+Dz, Hm], b1: [Hm], w2: [Hm], b2: scalar.
    Returns alpha: [M] (softmax into beta_m happens on the rust side,
    where absent modalities are masked).
    """
    m = z.shape[0]
    x = jnp.concatenate([jnp.broadcast_to(p, (m, p.shape[0])), z], axis=-1)
    h = jax.nn.relu(x @ w1 + b1)
    return h @ w2 + b2


def attention_ref(q, k, v, mask):
    """Masked multi-head attention over one head-batch.

    q: [H, Sq, Dh], k/v: [H, Sk, Dh], mask: [Sq, Sk] additive (0 or large
    negative). Returns [H, Sq, Dh].
    """
    dh = q.shape[-1]
    s = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(jnp.float32(dh))
    s = s + mask[None, :, :]
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, v)


def token_prune_ref(tokens, imp, tau, keep):
    """Order-preserving compaction of tokens with importance >= tau (Eq. 4).

    tokens: [N, D]; imp: [N]; tau: scalar threshold; keep: static capacity.
    Returns (pruned [keep, D] zero-padded, idx [keep] source index or -1,
    count scalar int32 = min(#selected, keep)).
    """
    n, d = tokens.shape
    sel_mask = imp >= tau
    rank = jnp.cumsum(sel_mask.astype(jnp.int32)) - 1  # rank among selected
    sel = sel_mask & (rank < keep)
    # Route rejected rows to a scratch slot `keep`; selected ranks are unique.
    dest = jnp.where(sel, rank, keep)
    out = jnp.zeros((keep + 1, d), tokens.dtype).at[dest].set(tokens)[:keep]
    idx = jnp.full((keep + 1,), -1, jnp.int32).at[dest].set(
        jnp.arange(n, dtype=jnp.int32)
    )[:keep]
    count = jnp.minimum(jnp.sum(sel_mask.astype(jnp.int32)), keep)
    return out, idx, count
