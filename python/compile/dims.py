"""Shared shape constants for the MSAO model stack.

These are the single source of truth for every AOT artifact; the rust side
reads the same values from artifacts/manifest.json (emitted by aot.py).

Sequence layout (slot ranges are fixed so one artifact serves all inputs):
    [0,   192) visual tokens   (image patches or pooled video-frame tokens)
    [192, 224) audio tokens
    [224, 288) text tokens
    [288, 352) generated tokens
"""

VOCAB = 384          # 0..255 bytes, 256..263 specials, 264..383 answer tokens
PAD, BOS, EOS, SEP = 256, 257, 258, 259
ANS_BASE = 264       # answer vocabulary for the synthetic VQA task

# vision front-end
GRID = 16            # patch grid -> 16x16 = 256 patches
N_PATCH = GRID * GRID
PATCH_DIM = 192      # 8x8 RGB patch, flattened
D_ENC = 128          # shared vision/audio encoder width
C_FEAT = 32          # probe feature-map channels
N_FRAMES = 8         # max video frames
FRAME_TOK = 32       # pooled tokens contributed per video frame

# audio front-end
AUDIO_T = 32         # audio feature frames
AUDIO_D = 80         # mel-style feature dim

# sequence slots
VIS_SLOTS = 192      # retained visual tokens after pruning (cap)
AUD_SLOTS = 32
TEXT_SLOTS = 64
GEN_SLOTS = 64
S_PRE = VIS_SLOTS + AUD_SLOTS + TEXT_SLOTS            # 288
S_MAX = S_PRE + GEN_SLOTS                             # 352
VIS_OFF, AUD_OFF, TEXT_OFF, GEN_OFF = 0, 192, 224, 288

# probe dims
LSH_K = 64           # number of hash functions (Eq. 5)
D_PROBE = 64         # modal-probe embedding width
N_MODALITIES = 4     # text, image, video, audio

# speculative decoding
N_SPEC = 6           # prev token + up to 5 draft tokens (N_max = 5)

DH = 32              # head dim (both models)


class ModelCfg:
    """Transformer hyper-parameters for one model variant."""

    def __init__(self, name, d, n_layers, n_heads, ffn):
        self.name = name
        self.d = d
        self.n_layers = n_layers
        self.n_heads = n_heads
        self.ffn = ffn
        assert d == n_heads * DH

    @property
    def n_params(self):
        per_layer = 4 * self.d * self.d + 2 * self.d * self.ffn
        return VOCAB * self.d + S_MAX * self.d + self.n_layers * per_layer


DRAFT = ModelCfg("draft", d=128, n_layers=4, n_heads=4, ffn=512)
FULL = ModelCfg("full", d=192, n_layers=6, n_heads=6, ffn=768)
