"""Synthetic multimodal data distribution — the python half.

The probe heads are *trained* (logistic regression / few-step SGD) on
samples from this distribution at AOT time; the rust workload generator
(rust/src/workload) draws from the same distribution (statistically, not
bit-identically) at run time. This mirrors the paper's setup where the
lightweight probing network is trained offline and generalizes to the
benchmark inputs.

Distribution contract (keep in sync with rust/src/workload/generator.rs):
  - images: GRID x GRID patches; a rectangular salient region of
    SAL_MIN..SAL_MAX patches gets structured high-energy content
    (sin ramp * SAL_AMP + noise); background is low-energy noise
    (BG_AMP * N(0,1)).
  - video: N_FRAMES frames; each frame either repeats the previous one
    plus DRIFT noise (static) or is freshly sampled (dynamic scene cut).
  - audio: AUDIO_T x AUDIO_D smooth noise (sum of random sinusoids).
  - questions: template text with a modality keyword; the relevant
    modality is the classification target of the modal probe.
"""

import numpy as np

from .dims import (
    AUDIO_D,
    AUDIO_T,
    GRID,
    N_PATCH,
    PATCH_DIM,
    TEXT_SLOTS,
    BOS,
    SEP,
)

SAL_AMP = 1.6
BG_AMP = 0.35
SAL_MIN, SAL_MAX = 3, 8  # salient rectangle side in patches
DRIFT = 0.05

# Keyword templates per modality (index order: text, image, video, audio —
# matches sparsity::Modality on the rust side).
TEMPLATES = [
    ["define the word", "what does the phrase mean", "spell the term"],
    ["what color is the object", "describe the picture", "what shape is shown in the image"],
    ["what happens in the video", "describe the motion in the clip", "what moves across the frames"],
    ["what sound is heard", "describe the audio", "who is the speaker in the recording"],
]


def make_image(rng: np.random.Generator):
    """Returns (patches [N_PATCH, PATCH_DIM], salient_mask [N_PATCH])."""
    patches = BG_AMP * rng.standard_normal((N_PATCH, PATCH_DIM))
    w = rng.integers(SAL_MIN, SAL_MAX + 1)
    h = rng.integers(SAL_MIN, SAL_MAX + 1)
    r0 = rng.integers(0, GRID - h + 1)
    c0 = rng.integers(0, GRID - w + 1)
    mask = np.zeros((GRID, GRID), bool)
    mask[r0 : r0 + h, c0 : c0 + w] = True
    mask = mask.reshape(-1)
    ramp = np.sin(np.linspace(0, 6 * np.pi, PATCH_DIM)) * SAL_AMP
    n_sal = int(mask.sum())
    patches[mask] = ramp[None, :] + SAL_AMP * 0.5 * rng.standard_normal(
        (n_sal, PATCH_DIM)
    )
    return patches.astype(np.float32), mask


def make_video(rng: np.random.Generator, n_frames: int, p_static: float = 0.6):
    """Returns (frames [n_frames, N_PATCH, PATCH_DIM], novel [n_frames])."""
    frames = np.zeros((n_frames, N_PATCH, PATCH_DIM), np.float32)
    novel = np.zeros(n_frames, bool)
    cur, _ = make_image(rng)
    frames[0] = cur
    novel[0] = True
    for t in range(1, n_frames):
        if rng.random() < p_static:
            cur = cur + DRIFT * rng.standard_normal(cur.shape).astype(np.float32)
        else:
            cur, _ = make_image(rng)
            novel[t] = True
        frames[t] = cur
    return frames, novel


def make_audio(rng: np.random.Generator):
    t = np.arange(AUDIO_T)[:, None]
    f = np.arange(AUDIO_D)[None, :]
    sig = sum(
        rng.standard_normal() * np.sin(2 * np.pi * (rng.random() * 0.1) * t + f * rng.random())
        for _ in range(4)
    )
    return (sig + 0.1 * rng.standard_normal((AUDIO_T, AUDIO_D))).astype(np.float32)


def make_question(rng: np.random.Generator, modality_idx: int):
    """Returns (token array [TEXT_SLOTS] i32, tlen)."""
    t = TEMPLATES[modality_idx][rng.integers(0, len(TEMPLATES[modality_idx]))]
    toks = [BOS] + [b for b in t.encode()][: TEXT_SLOTS - 2] + [SEP]
    tlen = len(toks)
    out = np.full(TEXT_SLOTS, 256, np.int32)  # PAD
    out[:tlen] = toks
    return out, tlen
