# MSAO build entry points. `make artifacts` is the one-time AOT compile
# step (python/JAX) that README, rust/tests/engine_golden.rs and
# python/tests/test_aot.py refer to; everything after it is cargo.

ARTIFACTS := artifacts

.PHONY: artifacts test pytest fmt clean

# Build the AOT artifacts (HLO graphs + weights + golden outputs) the
# rust engines load at runtime. Requires JAX; writes $(ARTIFACTS)/.
artifacts: $(ARTIFACTS)/manifest.json

$(ARTIFACTS)/manifest.json:
	cd python && python -m compile.aot --out ../$(ARTIFACTS)

# Tier-1 gate (ROADMAP.md). Engine-backed tests self-skip when
# artifacts/ is absent; run `make artifacts` first for the full suite.
test:
	cargo build --release
	cargo test -q

# Python-side tests (kernel/model/AOT smoke); builds artifacts first so
# test_aot.py does not skip.
pytest: artifacts
	cd python && python -m pytest tests -q

fmt:
	cargo fmt

clean:
	rm -rf $(ARTIFACTS) target
